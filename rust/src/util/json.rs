//! Minimal JSON reader/writer (serde is not in the offline crate set).
//!
//! Used for: the AOT `artifacts/manifest.json`, the golden test vectors
//! (`artifacts/testvec.json`), and the machine-readable bench outputs under
//! `target/bench_results/`. Supports the full JSON value model; numbers are
//! parsed as `f64` (sufficient for every producer/consumer in this repo).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Array of numbers as f32 (testvec payloads).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect())
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---------------- writer ----------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---------------- parser ----------------

    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Json::parse(&text)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or("bad \\u escape")?,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 char
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .map(|c| matches!(c, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("a", Json::num(1.0)),
            ("b", Json::Arr(vec![Json::num(2.5), Json::Bool(true), Json::Null])),
            ("c", Json::str("hi \"there\"\n")),
        ]);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"x": [1, 2, {"y": -3.5e2}], "z": "s"}"#).unwrap();
        assert_eq!(j.get("x").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("x").unwrap().as_arr().unwrap()[2]
                .get("y")
                .unwrap()
                .as_f64(),
            Some(-350.0)
        );
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{"blocks": [{"block": 256, "file": "lif_b256.hlo.txt"}],
                       "param_order": ["p22", "p21ex"], "num_params": 10}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("num_params").unwrap().as_usize(), Some(10));
        assert_eq!(
            j.get("blocks").unwrap().as_arr().unwrap()[0]
                .get("file")
                .unwrap()
                .as_str(),
            Some("lif_b256.hlo.txt")
        );
    }

    #[test]
    fn f32_vec() {
        let j = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(j.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{}x").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn integers_printed_without_fraction() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.25).to_string(), "3.25");
    }
}
