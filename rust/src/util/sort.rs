//! Sorting primitives for the construction hot path.
//!
//! The dominant cost of simulation preparation (paper Fig. 6b) is sorting
//! the connection array by source-neuron index and keeping the (R, L) maps
//! sorted (Eq. 3). On the GPU the reference implementation uses radix-based
//! device sorts; here we provide an LSD radix sort on `u64` keys with a
//! permutation payload, which is also the §Perf optimization target for the
//! coordinator.

/// Compute the permutation that stably sorts `keys` ascending.
///
/// LSD radix sort, 8 bits per digit, skipping digits that are constant over
/// the whole key range (common: keys are small node indexes).
pub fn argsort_u64(keys: &[u64]) -> Vec<u32> {
    let n = keys.len();
    let mut perm: Vec<u32> = (0..n as u32).collect();
    if n <= 1 {
        return perm;
    }
    // Which digits vary?
    let mut or_all = 0u64;
    let mut and_all = u64::MAX;
    for &k in keys {
        or_all |= k;
        and_all &= k;
    }
    let varying = or_all ^ and_all;
    let mut tmp: Vec<u32> = vec![0; n];
    let mut counts = [0usize; 256];
    for pass in 0..8 {
        let shift = pass * 8;
        if (varying >> shift) & 0xFF == 0 {
            continue;
        }
        counts.fill(0);
        for &i in perm.iter() {
            let d = ((keys[i as usize] >> shift) & 0xFF) as usize;
            counts[d] += 1;
        }
        let mut sum = 0usize;
        for c in counts.iter_mut() {
            let t = *c;
            *c = sum;
            sum += t;
        }
        for &i in perm.iter() {
            let d = ((keys[i as usize] >> shift) & 0xFF) as usize;
            tmp[counts[d]] = i;
            counts[d] += 1;
        }
        std::mem::swap(&mut perm, &mut tmp);
    }
    perm
}

/// Apply a permutation to a slice, out of place.
pub fn apply_perm<T: Copy>(perm: &[u32], xs: &[T]) -> Vec<T> {
    perm.iter().map(|&i| xs[i as usize]).collect()
}

/// Sort `u32` values ascending via the radix path.
pub fn sort_u32(xs: &mut Vec<u32>) {
    let keys: Vec<u64> = xs.iter().map(|&x| x as u64).collect();
    let perm = argsort_u64(&keys);
    *xs = apply_perm(&perm, xs);
}

/// Merge a sorted list of *new* values into a sorted vector, dropping values
/// already present (set-union merge). Returns the number inserted. This is
/// the map-update primitive of Eqs. 6–7: `S/R/L` stay sorted after every
/// `RemoteConnect` call.
pub fn merge_sorted_unique(dst: &mut Vec<u32>, new_sorted: &[u32]) -> usize {
    debug_assert!(new_sorted.windows(2).all(|w| w[0] <= w[1]));
    if new_sorted.is_empty() {
        return 0;
    }
    let mut merged = Vec::with_capacity(dst.len() + new_sorted.len());
    let (mut i, mut j) = (0usize, 0usize);
    let mut inserted = 0usize;
    while i < dst.len() || j < new_sorted.len() {
        if j >= new_sorted.len() {
            merged.extend_from_slice(&dst[i..]);
            break;
        }
        if i >= dst.len() {
            let v = new_sorted[j];
            if merged.last() != Some(&v) {
                merged.push(v);
                inserted += 1;
            }
            j += 1;
            continue;
        }
        let (a, b) = (dst[i], new_sorted[j]);
        if a < b {
            merged.push(a);
            i += 1;
        } else if a == b {
            merged.push(a);
            i += 1;
            j += 1;
        } else {
            if merged.last() != Some(&b) {
                merged.push(b);
                inserted += 1;
            }
            j += 1;
        }
    }
    *dst = merged;
    inserted
}

/// Binary search in a sorted slice; `Some(pos)` if found.
#[inline]
pub fn bsearch(xs: &[u32], v: u32) -> Option<usize> {
    xs.binary_search(&v).ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn argsort_sorts_random_keys() {
        let mut r = Rng::new(1);
        let keys: Vec<u64> = (0..5000).map(|_| r.next_u64() >> 20).collect();
        let perm = argsort_u64(&keys);
        let sorted = apply_perm(&perm, &keys);
        assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
        // permutation property
        let mut p2 = perm.clone();
        p2.sort_unstable();
        assert_eq!(p2, (0..5000u32).collect::<Vec<_>>());
    }

    #[test]
    fn argsort_is_stable() {
        // equal keys keep original order (required for deterministic builds)
        let keys = vec![3u64, 1, 3, 1, 3];
        let perm = argsort_u64(&keys);
        assert_eq!(perm, vec![1, 3, 0, 2, 4]);
    }

    #[test]
    fn argsort_empty_and_single() {
        assert!(argsort_u64(&[]).is_empty());
        assert_eq!(argsort_u64(&[7]), vec![0]);
    }

    #[test]
    fn argsort_constant_keys() {
        let keys = vec![5u64; 100];
        assert_eq!(argsort_u64(&keys), (0..100u32).collect::<Vec<_>>());
    }

    #[test]
    fn argsort_matches_std_sort() {
        let mut r = Rng::new(9);
        for n in [2usize, 17, 255, 1024] {
            let keys: Vec<u64> = (0..n).map(|_| r.next_u64()).collect();
            let perm = argsort_u64(&keys);
            let mut expect = keys.clone();
            expect.sort_unstable();
            assert_eq!(apply_perm(&perm, &keys), expect);
        }
    }

    #[test]
    fn merge_union_semantics() {
        let mut dst = vec![2, 5, 9];
        let ins = merge_sorted_unique(&mut dst, &[1, 5, 5, 7, 9, 12]);
        assert_eq!(dst, vec![1, 2, 5, 7, 9, 12]);
        assert_eq!(ins, 3); // 1, 7, 12
    }

    #[test]
    fn merge_into_empty_dedups() {
        let mut dst = vec![];
        let ins = merge_sorted_unique(&mut dst, &[3, 3, 4]);
        assert_eq!(dst, vec![3, 4]);
        assert_eq!(ins, 2);
    }

    #[test]
    fn merge_empty_new() {
        let mut dst = vec![1, 2];
        assert_eq!(merge_sorted_unique(&mut dst, &[]), 0);
        assert_eq!(dst, vec![1, 2]);
    }

    #[test]
    fn sort_u32_works() {
        let mut xs = vec![9u32, 1, 1, 0, 7];
        sort_u32(&mut xs);
        assert_eq!(xs, vec![0, 1, 1, 7, 9]);
    }
}
