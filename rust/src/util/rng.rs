//! Deterministic pseudo-random number generation.
//!
//! The paper's construction algorithm depends on *aligned* RNG streams: the
//! generator `RNG[σ,τ]` is seeded identically on the source and the target
//! MPI process of every remote connection and consumed in lockstep, so the
//! `S` and `(R, L)` sequences stay aligned (Eq. 1) with zero communication.
//! That requires a generator whose stream is a pure function of its seed and
//! draw sequence — no global state, no platform dependence. We implement
//! SplitMix64 (seeding / stream derivation) and xoshiro256** (the working
//! generator), plus the distributions the simulator needs (uniform ranges,
//! normal, Poisson, exponential, binomial).

/// SplitMix64: used to expand seeds and derive independent streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Mix an arbitrary list of stream identifiers into a single 64-bit seed.
///
/// Used to derive the aligned per-(σ,τ) generators: both ranks compute
/// `stream_seed(master, &[TAG, σ, τ])` and obtain the same stream.
pub fn stream_seed(master: u64, ids: &[u64]) -> u64 {
    let mut sm = SplitMix64::new(master ^ 0xA076_1D64_78BD_642F);
    let mut acc = sm.next_u64();
    for &id in ids {
        let mut s = SplitMix64::new(acc ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        acc = s.next_u64();
    }
    acc
}

/// xoshiro256**: the simulator's working generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate from Box–Muller
    gauss_cache: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 expansion (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_cache: None,
        }
    }

    /// Derive a generator for a named sub-stream (order-independent of other
    /// streams; deterministic across ranks).
    pub fn stream(master: u64, ids: &[u64]) -> Self {
        Self::new(stream_seed(master, ids))
    }

    /// The full generator state (xoshiro words + Box–Muller cache) for
    /// checkpointing: `from_raw_state(raw_state())` continues the stream
    /// bit-identically.
    pub fn raw_state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_cache)
    }

    /// Rebuild a generator from [`Rng::raw_state`] output.
    pub fn from_raw_state(s: [u64; 4], gauss_cache: Option<f64>) -> Self {
        Self { s, gauss_cache }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut lo = m as u32;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform integer in `[0, n)` for 64-bit ranges.
    #[inline]
    pub fn below_u64(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit Lemire
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `f64` in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_cache.take() {
            return z;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.gauss_cache = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal deviate with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Exponential deviate with rate `lambda`.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let mut u = self.uniform();
        if u == 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / lambda
    }

    /// Poisson deviate. Knuth multiplication for small means, normal
    /// approximation (with continuity correction, clamped at 0) for large —
    /// accurate to well under the statistical noise of spike-count inputs.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let z = self.normal();
            let x = lambda + lambda.sqrt() * z + 0.5;
            if x < 0.0 {
                0
            } else {
                x as u64
            }
        }
    }

    /// Binomial deviate via inversion for small n, normal approx otherwise.
    pub fn binomial(&mut self, n: u64, p: f64) -> u64 {
        if p <= 0.0 || n == 0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        if n < 64 {
            let mut k = 0;
            for _ in 0..n {
                if self.uniform() < p {
                    k += 1;
                }
            }
            k
        } else {
            let mean = n as f64 * p;
            let sd = (n as f64 * p * (1.0 - p)).sqrt();
            let x = mean + sd * self.normal() + 0.5;
            x.clamp(0.0, n as f64) as u64
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn stream_seed_symmetric_usage() {
        // the aligned-RNG property: same ids -> same stream, any id change
        // -> different stream
        assert_eq!(stream_seed(7, &[1, 2, 3]), stream_seed(7, &[1, 2, 3]));
        assert_ne!(stream_seed(7, &[1, 2, 3]), stream_seed(7, &[1, 3, 2]));
        assert_ne!(stream_seed(7, &[1, 2, 3]), stream_seed(8, &[1, 2, 3]));
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_u64_large_range() {
        let mut r = Rng::new(3);
        let n = 1u64 << 40;
        for _ in 0..100 {
            assert!(r.below_u64(n) < n);
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(5);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_small_and_large_mean() {
        let mut r = Rng::new(13);
        for &lambda in &[0.1, 3.0, 25.0, 100.0, 1000.0] {
            let n = 20_000;
            let total: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = total as f64 / n as f64;
            let tol = 4.0 * (lambda / n as f64).sqrt() + 0.51; // CLT + rounding
            assert!(
                (mean - lambda).abs() < tol,
                "lambda={lambda} mean={mean}"
            );
        }
        assert_eq!(r.poisson(0.0), 0);
    }

    #[test]
    fn binomial_mean() {
        let mut r = Rng::new(17);
        let (n, p) = (1000u64, 0.3);
        let total: u64 = (0..2000).map(|_| r.binomial(n, p)).sum();
        let mean = total as f64 / 2000.0;
        assert!((mean - 300.0).abs() < 3.0, "mean={mean}");
        assert_eq!(r.binomial(10, 0.0), 0);
        assert_eq!(r.binomial(10, 1.0), 10);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(19);
        let total: f64 = (0..20_000).map(|_| r.exponential(2.0)).sum();
        assert!((total / 20_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
