//! Byte-capped tick-LRU over a dense slot table.
//!
//! Factored out of the procedural-connectivity fanout cache so the serve
//! subsystem's snapshot cache shares one audited eviction policy. The
//! design is deliberately simple and deterministic: a dense `Vec` slot
//! per key (no hashing), a monotonically increasing logical tick stamped
//! on every touch/insert, and strict min-tick eviction — given the same
//! access sequence, the same victims fall out in the same order.
//!
//! Two usage shapes are supported:
//!
//! - [`TickLru::admit`] — the closed loop used by `FanoutCache`: evict
//!   least-recently-used entries until the newcomer fits, reporting each
//!   victim to a callback (for allocation-tracker accounting).
//! - [`TickLru::victim`] / [`TickLru::remove`] — the open loop used by
//!   the serve snapshot cache, where some entries are *pinned* (a warm
//!   job is resuming from them) and must be skipped when choosing a
//!   victim.

/// Dense-slot byte-capped LRU. `T` is the cached value; byte sizes are
/// supplied by the caller at insert time (the cache never inspects `T`).
pub struct TickLru<T> {
    cap: u64,
    used: u64,
    tick: u64,
    slots: Vec<Option<(u64, u64, T)>>,
}

impl<T> TickLru<T> {
    pub fn new(n_slots: usize, cap_bytes: u64) -> Self {
        let mut slots = Vec::new();
        slots.resize_with(n_slots, || None);
        Self {
            cap: cap_bytes,
            used: 0,
            tick: 0,
            slots,
        }
    }

    /// Grow the slot table to at least `n` slots (never shrinks).
    pub fn ensure_slots(&mut self, n: usize) {
        if n > self.slots.len() {
            self.slots.resize_with(n, || None);
        }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn cap_bytes(&self) -> u64 {
        self.cap
    }

    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.iter().all(|s| s.is_none())
    }

    /// Live entry for `id`, refreshing its LRU tick.
    pub fn touch(&mut self, id: usize) -> Option<&T> {
        self.tick += 1;
        let tick = self.tick;
        match self.slots.get_mut(id) {
            Some(Some((last, _, v))) => {
                *last = tick;
                Some(v)
            }
            _ => None,
        }
    }

    /// Live entry for `id` without refreshing its tick.
    pub fn peek(&self, id: usize) -> Option<&T> {
        match self.slots.get(id) {
            Some(Some((_, _, v))) => Some(v),
            _ => None,
        }
    }

    /// Mutable live entry for `id` without refreshing its tick.
    pub fn peek_mut(&mut self, id: usize) -> Option<&mut T> {
        match self.slots.get_mut(id) {
            Some(Some((_, _, v))) => Some(v),
            _ => None,
        }
    }

    /// Insert unconditionally (no eviction), stamping a fresh tick. The
    /// slot must be free; the caller is responsible for staying under
    /// budget via [`Self::victim`] + [`Self::remove`], or should use
    /// [`Self::admit`] instead.
    pub fn insert(&mut self, id: usize, value: T, bytes: u64) {
        debug_assert!(self.slots[id].is_none(), "insert over a live entry");
        self.tick += 1;
        self.used += bytes;
        self.slots[id] = Some((self.tick, bytes, value));
    }

    /// Remove `id`'s entry, returning the value and its byte size.
    pub fn remove(&mut self, id: usize) -> Option<(T, u64)> {
        match self.slots.get_mut(id).and_then(|s| s.take()) {
            Some((_, bytes, v)) => {
                self.used -= bytes;
                Some((v, bytes))
            }
            None => None,
        }
    }

    /// Least-recently-used live entry whose `(id, value)` is not excused
    /// by `skip`. Ties cannot occur (ticks are unique).
    pub fn victim(&self, mut skip: impl FnMut(usize, &T) -> bool) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|(t, _, v)| (t, i, v)))
            .filter(|&(_, i, v)| !skip(i, v))
            .min_by_key(|&(t, _, _)| t)
            .map(|(_, i, _)| i)
    }

    /// Insert with closed-loop eviction: evict min-tick victims until
    /// `bytes` fits under the cap, reporting each `(id, value, bytes)`
    /// victim to `on_evict`. A value larger than the whole budget is
    /// rejected (returns `false`, `on_evict` untouched).
    pub fn admit(
        &mut self,
        id: usize,
        value: T,
        bytes: u64,
        mut on_evict: impl FnMut(usize, T, u64),
    ) -> bool {
        if bytes > self.cap {
            return false;
        }
        while self.used + bytes > self.cap {
            let Some(v) = self.victim(|_, _| false) else {
                break;
            };
            if let Some((old, ob)) = self.remove(v) {
                on_evict(v, old, ob);
            }
        }
        self.insert(id, value, bytes);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn touch_refreshes_and_victim_is_min_tick() {
        let mut lru = TickLru::new(4, 100);
        lru.insert(0, "a", 10);
        lru.insert(1, "b", 10);
        lru.insert(2, "c", 10);
        assert_eq!(lru.touch(0), Some(&"a")); // 0 is now freshest
        assert_eq!(lru.victim(|_, _| false), Some(1));
        assert_eq!(lru.victim(|i, _| i == 1), Some(2)); // skip pins
        assert_eq!(lru.peek(1), Some(&"b")); // peek does not refresh
        assert_eq!(lru.victim(|_, _| false), Some(1));
        assert_eq!(lru.len(), 3);
        assert_eq!(lru.used_bytes(), 30);
    }

    #[test]
    fn admit_evicts_lru_until_fit_and_rejects_oversize() {
        let mut lru = TickLru::new(4, 25);
        assert!(lru.admit(0, "a", 10, |_, _, _| panic!("no eviction")));
        assert!(lru.admit(1, "b", 10, |_, _, _| panic!("no eviction")));
        let mut evicted = Vec::new();
        assert!(lru.admit(2, "c", 10, |i, v, b| evicted.push((i, v, b))));
        assert_eq!(evicted, vec![(0, "a", 10)]);
        assert_eq!(lru.used_bytes(), 20);
        // larger than the whole budget: rejected, state untouched
        assert!(!lru.admit(3, "huge", 26, |_, _, _| panic!("no eviction")));
        assert_eq!(lru.used_bytes(), 20);
        assert_eq!(lru.len(), 2);
    }

    #[test]
    fn remove_returns_bytes_and_frees_budget() {
        let mut lru = TickLru::new(2, 20);
        lru.insert(0, 7u32, 12);
        assert_eq!(lru.remove(0), Some((7, 12)));
        assert_eq!(lru.remove(0), None);
        assert_eq!(lru.used_bytes(), 0);
        assert!(lru.is_empty());
    }

    #[test]
    fn ensure_slots_grows_but_never_shrinks() {
        let mut lru: TickLru<u8> = TickLru::new(2, 10);
        lru.ensure_slots(5);
        assert_eq!(lru.n_slots(), 5);
        lru.ensure_slots(1);
        assert_eq!(lru.n_slots(), 5);
        lru.insert(4, 9, 1);
        assert_eq!(lru.touch(4), Some(&9));
    }
}
