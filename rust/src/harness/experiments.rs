//! Shared experiment drivers for the paper's tables and figures.
//!
//! Each bench binary (rust/benches/) calls into these, prints the
//! paper-style table and writes machine-readable results to
//! `target/bench_results/<name>.json`.

use std::path::PathBuf;

use crate::engine::{SimConfig, SimResult};
use crate::models::balanced::{build_balanced, BalancedConfig};
use crate::remote::GpuMemLevel;
use crate::util::json::Json;
use crate::util::table::mean_std;

/// Aggregated per-configuration metrics (mean over ranks and repeats).
#[derive(Clone, Debug, Default)]
pub struct Agg {
    pub node_creation_s: f64,
    pub local_conn_s: f64,
    pub remote_conn_s: f64,
    pub creation_and_connection_s: f64,
    pub preparation_s: f64,
    pub construction_s: f64,
    pub rtf: f64,
    pub rtf_sd: f64,
    pub device_peak: f64,
    pub device_peak_sd: f64,
    /// host-side allocation tracking (memory/tracker.rs), mean over ranks
    pub host_peak: f64,
    pub host_peak_sd: f64,
    pub host_current: f64,
    pub n_neurons: f64,
    pub n_connections: f64,
    pub n_images: f64,
    /// communication volume, mean per rank over the whole run
    pub p2p_messages: f64,
    pub p2p_bytes: f64,
    pub coll_calls: f64,
    pub coll_bytes: f64,
    /// effective exchange-batching interval (steps; mean over ranks —
    /// identical on every rank of a world)
    pub exchange_interval: f64,
}

/// Aggregate over all ranks of all repeats.
pub fn aggregate(runs: &[Vec<SimResult>]) -> Agg {
    let all: Vec<&SimResult> = runs.iter().flatten().collect();
    let f = |get: &dyn Fn(&SimResult) -> f64| -> (f64, f64) {
        let xs: Vec<f64> = all.iter().map(|r| get(r)).collect();
        mean_std(&xs)
    };
    let (node_creation_s, _) = f(&|r| r.phases.node_creation.as_secs_f64());
    let (local_conn_s, _) = f(&|r| r.phases.local_connection.as_secs_f64());
    let (remote_conn_s, _) = f(&|r| r.phases.remote_connection.as_secs_f64());
    let (creation_and_connection_s, _) =
        f(&|r| r.phases.creation_and_connection().as_secs_f64());
    let (preparation_s, _) = f(&|r| r.phases.preparation.as_secs_f64());
    let (construction_s, _) = f(&|r| r.phases.construction().as_secs_f64());
    let (rtf, rtf_sd) = f(&|r| r.rtf);
    let (device_peak, device_peak_sd) = f(&|r| r.device_peak as f64);
    let (host_peak, host_peak_sd) = f(&|r| r.host_peak as f64);
    let (host_current, _) = f(&|r| r.host_current as f64);
    let (n_neurons, _) = f(&|r| r.n_neurons as f64);
    let (n_connections, _) = f(&|r| r.n_connections as f64);
    let (n_images, _) = f(&|r| r.n_images as f64);
    let (p2p_messages, _) = f(&|r| r.p2p_messages as f64);
    let (p2p_bytes, _) = f(&|r| r.p2p_bytes as f64);
    let (coll_calls, _) = f(&|r| r.coll_calls as f64);
    let (coll_bytes, _) = f(&|r| r.coll_bytes as f64);
    let (exchange_interval, _) = f(&|r| r.exchange_interval as f64);
    Agg {
        node_creation_s,
        local_conn_s,
        remote_conn_s,
        creation_and_connection_s,
        preparation_s,
        construction_s,
        rtf,
        rtf_sd,
        device_peak,
        device_peak_sd,
        host_peak,
        host_peak_sd,
        host_current,
        n_neurons,
        n_connections,
        n_images,
        p2p_messages,
        p2p_bytes,
        coll_calls,
        coll_bytes,
        exchange_interval,
    }
}

impl Agg {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("node_creation_s", Json::num(self.node_creation_s)),
            ("local_conn_s", Json::num(self.local_conn_s)),
            ("remote_conn_s", Json::num(self.remote_conn_s)),
            (
                "creation_and_connection_s",
                Json::num(self.creation_and_connection_s),
            ),
            ("preparation_s", Json::num(self.preparation_s)),
            ("construction_s", Json::num(self.construction_s)),
            ("rtf", Json::num(self.rtf)),
            ("rtf_sd", Json::num(self.rtf_sd)),
            ("device_peak", Json::num(self.device_peak)),
            ("device_peak_sd", Json::num(self.device_peak_sd)),
            ("host_peak", Json::num(self.host_peak)),
            ("host_peak_sd", Json::num(self.host_peak_sd)),
            ("host_current", Json::num(self.host_current)),
            ("n_neurons", Json::num(self.n_neurons)),
            ("n_connections", Json::num(self.n_connections)),
            ("n_images", Json::num(self.n_images)),
            ("p2p_messages", Json::num(self.p2p_messages)),
            ("p2p_bytes", Json::num(self.p2p_bytes)),
            ("coll_calls", Json::num(self.coll_calls)),
            ("coll_bytes", Json::num(self.coll_bytes)),
            ("exchange_interval", Json::num(self.exchange_interval)),
        ])
    }
}

/// Write a bench's JSON result under `target/bench_results/`.
pub fn write_result(name: &str, value: &Json) {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join("bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("{name}.json"));
    if std::fs::write(&path, value.to_string()).is_ok() {
        println!("[written {}]", path.display());
    }
}

/// A weak-scaling measurement point for the balanced network.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub virtual_ranks: usize,
    pub level: GpuMemLevel,
    /// true = estimation mode (k live ranks dry-running the virtual world)
    pub estimated: bool,
    pub agg: Agg,
}

/// Run the balanced-network weak-scaling protocol (Figs. 4–6, 10–11):
/// live runs for small worlds, the paper's estimation methodology above
/// `max_live_ranks`.
#[allow(clippy::too_many_arguments)]
pub fn balanced_weak_scaling(
    rank_counts: &[usize],
    levels: &[GpuMemLevel],
    bal: &BalancedConfig,
    sim_cfg: &SimConfig,
    max_live_ranks: usize,
    live_repeats: usize,
    estimate_live: usize,
    t_ms: f64,
) -> Vec<ScalingPoint> {
    let mut out = Vec::new();
    for &vr in rank_counts {
        for &level in levels {
            let mut cfg = sim_cfg.clone();
            cfg.level = level;
            let bal = bal.clone();
            let builder =
                move |sim: &mut crate::engine::Simulator| build_balanced(sim, &bal);
            if vr <= max_live_ranks {
                let mut runs = Vec::new();
                for rep in 0..live_repeats {
                    let mut c = cfg.clone();
                    c.seed = cfg.seed + rep as u64;
                    let r = if t_ms > 0.0 {
                        crate::harness::run_cluster(vr, &c, &builder, t_ms)
                    } else {
                        crate::harness::run_construction_only(vr, &c, &builder)
                    }
                    .expect("live run");
                    runs.push(r);
                }
                out.push(ScalingPoint {
                    virtual_ranks: vr,
                    level,
                    estimated: false,
                    agg: aggregate(&runs),
                });
            } else {
                let r = crate::harness::estimate_cluster(
                    estimate_live.min(vr),
                    vr,
                    &cfg,
                    &builder,
                )
                .expect("estimation run");
                out.push(ScalingPoint {
                    virtual_ranks: vr,
                    level,
                    estimated: true,
                    agg: aggregate(&[r]),
                });
            }
        }
    }
    out
}

/// Analytic device-peak rows for Fig. 5's full-scale extrapolation:
/// (Leonardo nodes, predicted per-GPU peak bytes) at `scale`.
pub fn fig5_model_rows(nodes: &[u64], level: GpuMemLevel, scale: f64) -> Vec<(u64, u64)> {
    nodes
        .iter()
        .map(|&n| {
            let procs = n * 4; // 4 GPUs per Leonardo node
            let b = crate::memory::model::predict_balanced(scale, procs, level);
            (n, b.peak())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SimConfig;

    #[test]
    fn weak_scaling_runs_live_and_estimated() {
        let bal = BalancedConfig {
            scale: 0.002,
            k_scale: 0.002,
            ..Default::default()
        };
        let cfg = SimConfig::default();
        let pts = balanced_weak_scaling(
            &[2, 8],
            &[GpuMemLevel::L0, GpuMemLevel::L3],
            &bal,
            &cfg,
            4,   // live up to 4 ranks
            1,   // one repeat
            2,   // two live ranks for estimation
            0.0, // construction only
        );
        assert_eq!(pts.len(), 4);
        assert!(!pts[0].estimated && pts[2].estimated);
        for p in &pts {
            assert!(p.agg.n_connections > 0.0);
            assert!(p.agg.device_peak > 0.0);
        }
        // level 3 keeps maps on device: higher device peak than level 0
        let l0 = pts
            .iter()
            .find(|p| p.virtual_ranks == 8 && p.level == GpuMemLevel::L0)
            .unwrap();
        let l3 = pts
            .iter()
            .find(|p| p.virtual_ranks == 8 && p.level == GpuMemLevel::L3)
            .unwrap();
        assert!(l3.agg.device_peak >= l0.agg.device_peak);
    }

    #[test]
    fn fig5_model_plateau() {
        let rows = fig5_model_rows(&[1024, 3072, 4096], GpuMemLevel::L0, 20.0);
        let (_, a) = rows[1];
        let (_, b) = rows[2];
        assert!((b as f64 - a as f64).abs() / (a as f64) < 0.02);
    }
}
