//! Multi-rank experiment harness.
//!
//! [`Cluster::run`] spawns one thread per MPI rank (each with its own
//! communicator handle and, if configured, its own PJRT client), executes
//! an SPMD model-builder closure on every rank, and collects the per-rank
//! metrics. [`Cluster::estimate`] implements the paper's estimation
//! methodology: `k` live ranks dry-run network construction and simulation
//! preparation *as if* they were ranks of a much larger world — valid
//! because the construction algorithm is communication-free — which is how
//! the paper projects 4,096-node configurations from a single node.

pub mod experiments;

use std::path::Path;
use std::thread;

use anyhow::Context;

use crate::comm::{CommWorld, Communicator, NullComm, SocketComm, SocketConfig};
use crate::engine::{SimConfig, SimResult, Simulator};

/// Render a rank thread's panic payload for error reporting.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Join rank threads, converting a panic into an `anyhow::Error` that
/// carries the rank index — a failing rank must not abort the whole
/// cluster process without context.
fn join_ranks(
    handles: Vec<thread::ScopedJoinHandle<'_, anyhow::Result<SimResult>>>,
) -> Vec<anyhow::Result<SimResult>> {
    handles
        .into_iter()
        .enumerate()
        .map(|(rank, h)| match h.join() {
            Ok(res) => res.with_context(|| format!("rank {rank} failed")),
            Err(payload) => Err(anyhow::anyhow!(
                "rank {rank} panicked: {}",
                panic_message(payload.as_ref())
            )),
        })
        .collect()
}

/// An SPMD model script: runs identically on every rank, building that
/// rank's share of the network (`Create`/`Connect`/`RemoteConnect` calls
/// with identical arguments everywhere).
pub trait ModelBuilder: Sync {
    fn build(&self, sim: &mut Simulator);
}

impl<F: Fn(&mut Simulator) + Sync> ModelBuilder for F {
    fn build(&self, sim: &mut Simulator) {
        self(sim)
    }
}

/// Run a live simulation over `n_ranks` thread-ranks: build, prepare,
/// propagate `t_ms`, return per-rank results (rank order).
pub fn run_cluster<M: ModelBuilder>(
    n_ranks: usize,
    cfg: &SimConfig,
    model: &M,
    t_ms: f64,
) -> anyhow::Result<Vec<SimResult>> {
    let world = CommWorld::new(n_ranks);
    let comms = world.communicators();
    let results: Vec<anyhow::Result<SimResult>> = thread::scope(|s| {
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                let cfg = cfg.clone();
                s.spawn(move || -> anyhow::Result<SimResult> {
                    let mut sim = Simulator::new(Box::new(comm), cfg);
                    model.build(&mut sim);
                    sim.prepare()?;
                    sim.simulate(t_ms)
                })
            })
            .collect();
        join_ranks(handles)
    });
    results.into_iter().collect()
}

/// Estimation (dry-run) mode: each of the `live_ranks` behaves as the
/// corresponding rank of a *virtual* world of `virtual_ranks`, performing
/// construction + preparation only (no propagation, no communication).
///
/// Returns one result per live rank; memory/time metrics are samples of the
/// virtual configuration's per-rank distribution (the paper averages over
/// several such runs, cf. "estimated" vs "simulated" in Figs. 5-6).
pub fn estimate_cluster<M: ModelBuilder>(
    live_ranks: usize,
    virtual_ranks: usize,
    cfg: &SimConfig,
    model: &M,
) -> anyhow::Result<Vec<SimResult>> {
    assert!(live_ranks <= virtual_ranks);
    let results: Vec<anyhow::Result<SimResult>> = thread::scope(|s| {
        let handles: Vec<_> = (0..live_ranks)
            .map(|rank| {
                let cfg = cfg.clone();
                s.spawn(move || -> anyhow::Result<SimResult> {
                    let comm = NullComm::new(rank, virtual_ranks);
                    let mut sim = Simulator::new(Box::new(comm), cfg);
                    model.build(&mut sim);
                    sim.prepare()?;
                    Ok(sim.result(0.0, 0.0))
                })
            })
            .collect();
        join_ranks(handles)
    });
    results.into_iter().collect()
}

/// Run construction + preparation only on a live world (no propagation):
/// used by construction-time benches where spiking is irrelevant.
pub fn run_construction_only<M: ModelBuilder>(
    n_ranks: usize,
    cfg: &SimConfig,
    model: &M,
) -> anyhow::Result<Vec<SimResult>> {
    let world = CommWorld::new(n_ranks);
    let comms = world.communicators();
    let results: Vec<anyhow::Result<SimResult>> = thread::scope(|s| {
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                let cfg = cfg.clone();
                s.spawn(move || -> anyhow::Result<SimResult> {
                    let mut sim = Simulator::new(Box::new(comm), cfg);
                    model.build(&mut sim);
                    sim.prepare()?;
                    Ok(sim.result(0.0, 0.0))
                })
            })
            .collect();
        join_ranks(handles)
    });
    results.into_iter().collect()
}

/// Run a live cluster and checkpoint it: build, prepare, propagate `t_ms`
/// (0 = construction cache: save immediately after preparation), then
/// write one snapshot file per rank into `dir` (`rank_<r>.snap`).
///
/// Every rank reaches `save_snapshot` at the same step, which satisfies
/// its collective flush of any spike records still batched inside the
/// current exchange interval (see `Simulator::flush_exchange`).
pub fn run_cluster_with_snapshot<M: ModelBuilder>(
    n_ranks: usize,
    cfg: &SimConfig,
    model: &M,
    t_ms: f64,
    dir: &Path,
) -> anyhow::Result<Vec<SimResult>> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("cannot create snapshot directory {}", dir.display()))?;
    let world = CommWorld::new(n_ranks);
    let comms = world.communicators();
    let results: Vec<anyhow::Result<SimResult>> = thread::scope(|s| {
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                let cfg = cfg.clone();
                s.spawn(move || -> anyhow::Result<SimResult> {
                    let mut sim = Simulator::new(Box::new(comm), cfg);
                    model.build(&mut sim);
                    sim.prepare()?;
                    let res = if t_ms > 0.0 {
                        sim.simulate(t_ms)?
                    } else {
                        sim.result(0.0, 0.0)
                    };
                    let path = dir.join(crate::snapshot::rank_file_name(sim.rank()));
                    sim.save_snapshot(&path)?;
                    Ok(res)
                })
            })
            .collect();
        join_ranks(handles)
    });
    results.into_iter().collect()
}

/// Construct-and-cache in one pass: build, prepare, write the
/// construction snapshot (step 0, before any propagation) into `dir`,
/// then propagate `t_ms` in the *same* prepared simulators. The saved
/// files are exactly what [`run_cluster_with_snapshot`] with `t_ms = 0`
/// would have written, but the caller also gets the live `t_ms` results
/// without reloading — the cold path of the serve snapshot cache, whose
/// warm path ([`run_cluster_from_snapshot`] on `dir`) then reproduces
/// the returned spike trains bit-identically.
pub fn run_cluster_construct_save<M: ModelBuilder>(
    n_ranks: usize,
    cfg: &SimConfig,
    model: &M,
    t_ms: f64,
    dir: &Path,
) -> anyhow::Result<Vec<SimResult>> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("cannot create snapshot directory {}", dir.display()))?;
    let world = CommWorld::new(n_ranks);
    let comms = world.communicators();
    let results: Vec<anyhow::Result<SimResult>> = thread::scope(|s| {
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                let cfg = cfg.clone();
                s.spawn(move || -> anyhow::Result<SimResult> {
                    let mut sim = Simulator::new(Box::new(comm), cfg);
                    model.build(&mut sim);
                    sim.prepare()?;
                    let path = dir.join(crate::snapshot::rank_file_name(sim.rank()));
                    sim.save_snapshot(&path)?;
                    if t_ms > 0.0 {
                        sim.simulate(t_ms)
                    } else {
                        Ok(sim.result(0.0, 0.0))
                    }
                })
            })
            .collect();
        join_ranks(handles)
    });
    results.into_iter().collect()
}

/// Validate that `dir` holds a *complete* world of rank snapshot files
/// and return `(n_ranks, step_now)` from the lowest present rank's
/// header. A missing or partial file set fails with a "found K of N
/// rank snapshots" message naming the absent ranks — not a raw
/// `io::Error` from whichever file happened to be opened first.
pub fn snapshot_world(dir: &Path) -> anyhow::Result<(usize, u32)> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("cannot read snapshot directory {}", dir.display()))?;
    let mut found: Vec<usize> = Vec::new();
    for entry in entries {
        let name = entry
            .with_context(|| format!("cannot list snapshot directory {}", dir.display()))?
            .file_name();
        let name = name.to_string_lossy();
        if let Some(rank) = name
            .strip_prefix("rank_")
            .and_then(|s| s.strip_suffix(".snap"))
            .and_then(|s| s.parse::<usize>().ok())
        {
            found.push(rank);
        }
    }
    if found.is_empty() {
        anyhow::bail!("no rank snapshots (rank_<r>.snap) found in {}", dir.display());
    }
    found.sort_unstable();
    let lowest = found[0];
    let (_, n_ranks, step_now) =
        crate::engine::peek_world(&dir.join(crate::snapshot::rank_file_name(lowest)))?;
    let missing: Vec<usize> = (0..n_ranks).filter(|r| !found.contains(r)).collect();
    if !missing.is_empty() {
        let shown: Vec<String> = missing.iter().take(8).map(|r| r.to_string()).collect();
        let ellipsis = if missing.len() > 8 { ", ..." } else { "" };
        anyhow::bail!(
            "found {} of {} rank snapshots in {} (missing rank(s) {}{}) — \
             incomplete or interrupted save?",
            n_ranks - missing.len(),
            n_ranks,
            dir.display(),
            shown.join(", "),
            ellipsis
        );
    }
    Ok((n_ranks, step_now))
}

/// Restore a whole cluster from per-rank snapshot files in `dir` and
/// propagate `t_ms` of model time (0 = restore only, e.g. to measure
/// reload cost). The world size is read from the snapshot headers after
/// a completeness check ([`snapshot_world`]); construction and
/// preparation are skipped on every rank.
pub fn run_cluster_from_snapshot(dir: &Path, t_ms: f64) -> anyhow::Result<Vec<SimResult>> {
    let (n_ranks, _) = snapshot_world(dir)?;
    let world = CommWorld::new(n_ranks);
    let comms = world.communicators();
    let results: Vec<anyhow::Result<SimResult>> = thread::scope(|s| {
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                s.spawn(move || -> anyhow::Result<SimResult> {
                    let path = dir.join(crate::snapshot::rank_file_name(comm.rank()));
                    let mut sim = Simulator::load_snapshot(Box::new(comm), &path)?;
                    if t_ms > 0.0 {
                        sim.simulate(t_ms)
                    } else {
                        Ok(sim.result(0.0, 0.0))
                    }
                })
            })
            .collect();
        join_ranks(handles)
    });
    results.into_iter().collect()
}

/// Pick a free loopback rendezvous address: bind an ephemeral port, read
/// the assignment back, release it. The tiny bind race this leaves open is
/// irrelevant on a test/CI loopback; real deployments pass a fixed
/// `HOST:PORT`.
pub fn free_loopback_addr() -> anyhow::Result<String> {
    let l = std::net::TcpListener::bind("127.0.0.1:0").context("bind loopback port")?;
    Ok(l.local_addr().context("read loopback addr")?.to_string())
}

/// Run a live simulation with every rank holding a [`SocketComm`]: the
/// ranks are still threads of this process (so tests can compare full
/// per-rank results in one address space), but every spike packet and
/// collective travels through real TCP loopback connections — the exact
/// wire path the multi-process launcher uses. `socket` supplies the
/// rendezvous address and timeouts; rank and world are assigned here.
pub fn run_cluster_socket<M: ModelBuilder>(
    n_ranks: usize,
    cfg: &SimConfig,
    socket: &SocketConfig,
    model: &M,
    t_ms: f64,
) -> anyhow::Result<Vec<SimResult>> {
    let results: Vec<anyhow::Result<SimResult>> = thread::scope(|s| {
        let handles: Vec<_> = (0..n_ranks)
            .map(|rank| {
                let cfg = cfg.clone();
                let scfg = SocketConfig {
                    rank: Some(rank),
                    world: n_ranks,
                    ..socket.clone()
                };
                s.spawn(move || -> anyhow::Result<SimResult> {
                    let comm = SocketComm::connect(&scfg)?;
                    let mut sim = Simulator::new(Box::new(comm), cfg);
                    model.build(&mut sim);
                    sim.prepare()?;
                    sim.simulate(t_ms)
                })
            })
            .collect();
        join_ranks(handles)
    });
    results.into_iter().collect()
}

/// Run ONE rank of a (normally multi-process) world in this process:
/// build, prepare, simulate, then gather the world-combined spike hash —
/// the per-process body behind `nestgpu <cmd> --comm socket` and
/// `nestgpu launch`. The hash gather is collective, so every rank process
/// must run the same subcommand to completion.
pub fn run_rank<M: ModelBuilder>(
    comm: Box<dyn Communicator>,
    cfg: &SimConfig,
    model: &M,
    t_ms: f64,
) -> anyhow::Result<(SimResult, u64)> {
    let mut sim = Simulator::new(comm, cfg.clone());
    model.build(&mut sim);
    sim.prepare()?;
    let res = sim.simulate(t_ms)?;
    let hash = sim.world_spike_hash();
    Ok((res, hash))
}

/// One-rank counterpart of [`run_cluster_with_snapshot`]: propagate
/// `t_ms` (0 = construction cache), write this rank's snapshot into
/// `dir`, return the result and the world spike hash.
pub fn run_rank_with_snapshot<M: ModelBuilder>(
    comm: Box<dyn Communicator>,
    cfg: &SimConfig,
    model: &M,
    t_ms: f64,
    dir: &Path,
) -> anyhow::Result<(SimResult, u64)> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("cannot create snapshot directory {}", dir.display()))?;
    let mut sim = Simulator::new(comm, cfg.clone());
    model.build(&mut sim);
    sim.prepare()?;
    let res = if t_ms > 0.0 {
        sim.simulate(t_ms)?
    } else {
        sim.result(0.0, 0.0)
    };
    let path = dir.join(crate::snapshot::rank_file_name(sim.rank()));
    sim.save_snapshot(&path)?;
    let hash = sim.world_spike_hash();
    Ok((res, hash))
}

/// One-rank counterpart of [`run_cluster_from_snapshot`]: restore this
/// rank from its file in `dir` (the snapshot's recorded rank/world must
/// match the communicator's) and propagate `t_ms`.
pub fn run_rank_from_snapshot(
    comm: Box<dyn Communicator>,
    dir: &Path,
    t_ms: f64,
) -> anyhow::Result<(SimResult, u64)> {
    let path = dir.join(crate::snapshot::rank_file_name(comm.rank()));
    let mut sim = Simulator::load_snapshot(comm, &path)?;
    let res = if t_ms > 0.0 {
        sim.simulate(t_ms)?
    } else {
        sim.result(0.0, 0.0)
    };
    let hash = sim.world_spike_hash();
    Ok((res, hash))
}

/// Spawn `n_ranks` real OS processes running `exe args... --comm socket
/// --rank R --world N --rendezvous ADDR` and wait for all of them —
/// the engine behind `nestgpu launch`. Each child's output is drained by
/// its own thread (a full pipe must never stall a rank mid-collective).
/// Returns the per-rank outputs in rank order; any non-zero exit fails
/// with every failing rank's status and stderr.
pub fn run_cluster_processes(
    exe: &Path,
    n_ranks: usize,
    args: &[String],
    rendezvous: &str,
) -> anyhow::Result<Vec<std::process::Output>> {
    let mut children = Vec::new();
    for rank in 0..n_ranks {
        let child = std::process::Command::new(exe)
            .args(args)
            .args(["--comm", "socket"])
            .args(["--rank", &rank.to_string()])
            .args(["--world", &n_ranks.to_string()])
            .args(["--rendezvous", rendezvous])
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::piped())
            .spawn()
            .with_context(|| format!("spawn rank {rank} ({})", exe.display()))?;
        children.push(child);
    }
    let outputs: Vec<std::io::Result<std::process::Output>> = thread::scope(|s| {
        let handles: Vec<_> = children
            .into_iter()
            .map(|child| s.spawn(move || child.wait_with_output()))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("output-drain thread panicked"))
            .collect()
    });
    let mut results = Vec::new();
    let mut failures = Vec::new();
    for (rank, out) in outputs.into_iter().enumerate() {
        let out = out.with_context(|| format!("collect output of rank {rank}"))?;
        if !out.status.success() {
            failures.push(format!(
                "rank {rank} exited with {}: {}",
                out.status,
                String::from_utf8_lossy(&out.stderr).trim()
            ));
        }
        results.push(out);
    }
    if !failures.is_empty() {
        anyhow::bail!("{}", failures.join("\n"));
    }
    Ok(results)
}

/// Keep only the communicator-independent part of a world: helper to run a
/// single-rank simulation without threads (examples, tests).
pub fn run_single<M: ModelBuilder>(
    cfg: &SimConfig,
    model: &M,
    t_ms: f64,
) -> anyhow::Result<SimResult> {
    let world = CommWorld::new(1);
    let comm = world.communicators().pop().unwrap();
    let mut sim = Simulator::new(Box::new(comm), cfg.clone());
    model.build(&mut sim);
    sim.prepare()?;
    sim.simulate(t_ms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::connection::{ConnRule, NodeSet, SynSpec};
    use crate::node::LifParams;

    /// Two ranks, one remote connection 0->1, driven by a Poisson input on
    /// rank 0: the remote spike must reach rank 1's neuron.
    struct TinyModel;
    impl ModelBuilder for TinyModel {
        fn build(&self, sim: &mut Simulator) {
            let params = LifParams::default();
            let neurons = sim.create_neurons(4, &params);
            if sim.rank() == 0 {
                let gen = sim.create_poisson(50_000.0);
                sim.connect(&gen, &neurons, &ConnRule::AllToAll, &SynSpec::new(500.0, 1));
            }
            // remote: rank 0 neurons -> rank 1 neurons (SPMD call on both)
            sim.remote_connect(
                0,
                &NodeSet::range(0, 4),
                1,
                &NodeSet::range(0, 4),
                &ConnRule::AllToAll,
                &SynSpec::new(800.0, 2),
                None,
            );
        }
    }

    #[test]
    fn spikes_cross_ranks_p2p() {
        let cfg = SimConfig::default();
        let results = run_cluster(2, &cfg, &TinyModel, 50.0).unwrap();
        assert_eq!(results.len(), 2);
        let r0 = &results[0];
        let r1 = &results[1];
        assert!(r0.n_spikes > 0, "rank 0 neurons must fire under drive");
        assert!(
            r1.n_spikes > 0,
            "rank 1 neurons must fire from remote spikes alone"
        );
        assert!(r0.p2p_bytes > 0, "rank 0 must have sent spike packets");
        assert_eq!(r1.n_images, 4);
    }

    #[test]
    fn batched_exchange_is_bit_identical_and_cheaper() {
        // TinyModel's remote synapses have delay 2, so the auto interval
        // resolves to 2: half the p2p messages, identical spike output
        let per_step = SimConfig {
            exchange_interval: Some(1),
            ..Default::default()
        };
        let batched_cfg = SimConfig::default(); // None = auto (min delay)
        let r1 = run_cluster(2, &per_step, &TinyModel, 50.0).unwrap();
        let rb = run_cluster(2, &batched_cfg, &TinyModel, 50.0).unwrap();
        assert_eq!(r1[0].exchange_interval, 1);
        assert_eq!(rb[0].exchange_interval, 2);
        for (a, b) in r1.iter().zip(rb.iter()) {
            assert_eq!(a.spikes, b.spikes, "batching must not change spikes");
        }
        // message count never grows (the >=3x reduction on a dense workload
        // is asserted in tests/it_exchange.rs)
        assert!(rb[0].p2p_messages <= r1[0].p2p_messages);
        assert!(rb[0].p2p_bytes <= r1[0].p2p_bytes);
    }

    #[test]
    fn estimation_matches_live_structures() {
        // dry-run rank 1 of a virtual 2-rank world: structure sizes must
        // match the live run exactly
        let cfg = SimConfig::default();
        let live = run_cluster(2, &cfg, &TinyModel, 0.0).unwrap();
        let est = estimate_cluster(2, 2, &cfg, &TinyModel).unwrap();
        for (l, e) in live.iter().zip(est.iter()) {
            assert_eq!(l.n_neurons, e.n_neurons);
            assert_eq!(l.n_images, e.n_images);
            assert_eq!(l.n_connections, e.n_connections);
            assert_eq!(l.map_entries, e.map_entries);
        }
    }

    #[test]
    fn panicking_rank_reported_with_index() {
        // rank 1 panics during (communication-free) construction; the
        // cluster must surface an error naming the rank, not abort
        let cfg = SimConfig::default();
        let res = run_construction_only(2, &cfg, &|sim: &mut Simulator| {
            let _ = sim.create_neurons(1, &LifParams::default());
            if sim.rank() == 1 {
                panic!("intentional test panic");
            }
        });
        let err = res.unwrap_err().to_string();
        assert!(err.contains("rank 1"), "{err}");
        assert!(err.contains("intentional test panic"), "{err}");
    }

    #[test]
    fn socket_cluster_matches_thread_cluster() {
        // the full cross-backend matrix lives in tests/it_transport.rs;
        // this is the fast in-crate smoke check of the socket harness path
        let cfg = SimConfig::default();
        let thread = run_cluster(2, &cfg, &TinyModel, 30.0).unwrap();
        let socket = run_cluster_socket(
            2,
            &cfg,
            &SocketConfig::new(free_loopback_addr().unwrap(), 2),
            &TinyModel,
            30.0,
        )
        .unwrap();
        for (t, s) in thread.iter().zip(socket.iter()) {
            assert_eq!(t.spikes, s.spikes, "rank {}", t.rank);
        }
        // socket traffic counts whole frames (24-byte headers, empty
        // rounds included), so its byte count must exceed thread-comm's
        assert!(socket[0].p2p_bytes > thread[0].p2p_bytes);
    }

    #[test]
    fn single_rank_runs() {
        let cfg = SimConfig::default();
        let r = run_single(
            &cfg,
            &|sim: &mut Simulator| {
                let n = sim.create_neurons(10, &LifParams::default());
                let g = sim.create_poisson(20_000.0);
                sim.connect(&g, &n, &ConnRule::AllToAll, &SynSpec::new(300.0, 1));
                sim.connect(&n, &n, &ConnRule::FixedIndegree { k: 2 }, &SynSpec::new(10.0, 1));
            },
            20.0,
        )
        .unwrap();
        assert!(r.n_spikes > 0);
        assert_eq!(r.n_images, 0);
    }
}
