//! Explicit allocation tracker for the simulated GPU device.
//!
//! Every data structure of the simulator registers its residency (device or
//! host) and its size here; the tracker maintains current and peak byte
//! counts per memory kind. The GPU-memory-level machinery (§0.3.6) is what
//! decides *which* structures go where; the tracker is how Fig. 5's peak
//! curves are measured on this substrate.

/// Which memory a structure lives in. The paper's GPU memory levels move
/// remote-connection structures between the two.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemKind {
    /// Simulated GPU memory (the scarce resource; Fig. 5 tracks its peak).
    Device,
    /// Host (CPU) memory ("typically underutilized", §0.5).
    Host,
}

#[derive(Debug, Default, Clone, Copy)]
struct Usage {
    current: u64,
    peak: u64,
}

impl Usage {
    fn add(&mut self, bytes: u64) {
        self.current += bytes;
        self.peak = self.peak.max(self.current);
    }
    fn sub(&mut self, bytes: u64) {
        debug_assert!(self.current >= bytes, "free exceeds allocation");
        self.current = self.current.saturating_sub(bytes);
    }
}

/// Per-rank memory tracker.
#[derive(Debug, Default)]
pub struct Tracker {
    device: Usage,
    host: Usage,
    /// count of transient (alloc+free within one operation) device peaks
    pub transient_events: u64,
}

impl Tracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&mut self, kind: MemKind, bytes: u64) {
        match kind {
            MemKind::Device => self.device.add(bytes),
            MemKind::Host => self.host.add(bytes),
        }
    }

    pub fn free(&mut self, kind: MemKind, bytes: u64) {
        match kind {
            MemKind::Device => self.device.sub(bytes),
            MemKind::Host => self.host.sub(bytes),
        }
    }

    /// Account a transient buffer: allocated, used inside `f`, then freed.
    /// This is how construction temporaries (the `l`, `b`, `ũ`, `s̃` arrays
    /// of §0.3.3 and sort scratch) contribute to the *peak* without
    /// contributing to the steady state.
    pub fn transient<T>(&mut self, kind: MemKind, bytes: u64, f: impl FnOnce() -> T) -> T {
        self.alloc(kind, bytes);
        self.transient_events += 1;
        let out = f();
        self.free(kind, bytes);
        out
    }

    /// Adjust accounting when a tracked vector grows (old freed, new alloc'd).
    pub fn realloc(&mut self, kind: MemKind, old_bytes: u64, new_bytes: u64) {
        // order matters for peak fidelity: device reallocs hold both copies
        // momentarily (cudaMalloc+copy+free), so peak sees old+new.
        self.alloc(kind, new_bytes);
        self.free(kind, old_bytes);
    }

    pub fn current(&self, kind: MemKind) -> u64 {
        match kind {
            MemKind::Device => self.device.current,
            MemKind::Host => self.host.current,
        }
    }

    pub fn peak(&self, kind: MemKind) -> u64 {
        match kind {
            MemKind::Device => self.device.peak,
            MemKind::Host => self.host.peak,
        }
    }
}

/// A vector whose heap usage is registered with a [`Tracker`].
///
/// Grows in fixed-size blocks (`BLOCK_ELEMS` elements), mirroring the
/// paper's "arrays organized in fixed-size blocks that are allocated
/// dynamically in order to use GPU memory efficiently" (§0.3.1).
#[derive(Debug)]
pub struct TrackedVec<T: Copy> {
    data: Vec<T>,
    kind: MemKind,
    tracked_bytes: u64,
}

/// Elements per allocation block (64 KiB of u32).
pub const BLOCK_ELEMS: usize = 16 * 1024;

impl<T: Copy> TrackedVec<T> {
    pub fn new(kind: MemKind) -> Self {
        Self {
            data: Vec::new(),
            kind,
            tracked_bytes: 0,
        }
    }

    pub fn with_capacity(kind: MemKind, cap: usize, tr: &mut Tracker) -> Self {
        let mut v = Self::new(kind);
        v.reserve_blocks(cap, tr);
        v
    }

    fn reserve_blocks(&mut self, needed: usize, tr: &mut Tracker) {
        if needed <= self.data.capacity() {
            return;
        }
        // Capacity grows geometrically (like the device allocator pooling
        // blocks) but is *accounted* in fixed-size blocks; growing one
        // block at a time would make pushes quadratic (§Perf iteration 1).
        let geometric = self.data.capacity().saturating_mul(2);
        let new_cap = needed
            .max(geometric)
            .div_ceil(BLOCK_ELEMS)
            * BLOCK_ELEMS;
        self.data.reserve_exact(new_cap - self.data.len());
        let new_bytes = (self.data.capacity() * std::mem::size_of::<T>()) as u64;
        tr.realloc(self.kind, self.tracked_bytes, new_bytes);
        self.tracked_bytes = new_bytes;
    }

    pub fn push(&mut self, x: T, tr: &mut Tracker) {
        self.reserve_blocks(self.data.len() + 1, tr);
        self.data.push(x);
    }

    pub fn extend_from_slice(&mut self, xs: &[T], tr: &mut Tracker) {
        self.reserve_blocks(self.data.len() + xs.len(), tr);
        self.data.extend_from_slice(xs);
    }

    pub fn replace(&mut self, xs: Vec<T>, tr: &mut Tracker) {
        self.data.clear();
        self.extend_from_slice(&xs, tr);
    }

    pub fn as_slice(&self) -> &[T] {
        &self.data
    }
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data
    }
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    pub fn bytes(&self) -> u64 {
        self.tracked_bytes
    }
    pub fn kind(&self) -> MemKind {
        self.kind
    }

    /// Release the tracked bytes (call before drop when tracker is external).
    pub fn release(&mut self, tr: &mut Tracker) {
        tr.free(self.kind, self.tracked_bytes);
        self.tracked_bytes = 0;
        self.data = Vec::new();
    }
}

impl<T: Copy> std::ops::Index<usize> for TrackedVec<T> {
    type Output = T;
    fn index(&self, i: usize) -> &T {
        &self.data[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_transients() {
        let mut t = Tracker::new();
        t.alloc(MemKind::Device, 100);
        t.transient(MemKind::Device, 1000, || {});
        assert_eq!(t.current(MemKind::Device), 100);
        assert_eq!(t.peak(MemKind::Device), 1100);
        assert_eq!(t.transient_events, 1);
    }

    #[test]
    fn host_and_device_are_independent() {
        let mut t = Tracker::new();
        t.alloc(MemKind::Host, 50);
        t.alloc(MemKind::Device, 70);
        t.free(MemKind::Host, 50);
        assert_eq!(t.current(MemKind::Host), 0);
        assert_eq!(t.peak(MemKind::Host), 50);
        assert_eq!(t.current(MemKind::Device), 70);
    }

    #[test]
    fn realloc_peak_sees_both_copies() {
        let mut t = Tracker::new();
        t.alloc(MemKind::Device, 100);
        t.realloc(MemKind::Device, 100, 200);
        assert_eq!(t.current(MemKind::Device), 200);
        assert_eq!(t.peak(MemKind::Device), 300);
    }

    #[test]
    fn tracked_vec_grows_in_blocks() {
        let mut t = Tracker::new();
        let mut v: TrackedVec<u32> = TrackedVec::new(MemKind::Device);
        v.push(1, &mut t);
        assert_eq!(
            t.current(MemKind::Device),
            (BLOCK_ELEMS * 4) as u64,
            "first push allocates one block"
        );
        for i in 0..BLOCK_ELEMS {
            v.push(i as u32, &mut t);
        }
        assert_eq!(t.current(MemKind::Device), (2 * BLOCK_ELEMS * 4) as u64);
        assert_eq!(v.len(), BLOCK_ELEMS + 1);
    }

    #[test]
    fn tracked_vec_release() {
        let mut t = Tracker::new();
        let mut v: TrackedVec<u64> = TrackedVec::with_capacity(MemKind::Host, 10, &mut t);
        v.extend_from_slice(&[1, 2, 3], &mut t);
        assert!(t.current(MemKind::Host) > 0);
        v.release(&mut t);
        assert_eq!(t.current(MemKind::Host), 0);
    }

    #[test]
    fn tracked_vec_replace() {
        let mut t = Tracker::new();
        let mut v: TrackedVec<u32> = TrackedVec::new(MemKind::Device);
        v.extend_from_slice(&[5, 4, 3], &mut t);
        v.replace(vec![1, 2], &mut t);
        assert_eq!(v.as_slice(), &[1, 2]);
    }
}
