//! Analytic GPU-memory model for the scalable balanced network.
//!
//! The paper extrapolates configurations beyond what could be run (Fig. 5's
//! dashed "estimated" curves, the 4,096-node level-0 plateau, the JUPITER
//! projection in the Discussion). This module provides the corresponding
//! closed-form predictor for *this* implementation's data structures: given
//! the model scale, the number of processes, and the GPU memory level, it
//! returns the expected per-rank device-memory breakdown.
//!
//! The structural terms mirror §0.3:
//! - connections: 16 B/connection (u32 source, u32 target, f32 weight,
//!   u16 delay, u8 port, 1 B pad), sorted by source;
//! - p2p/collective maps: 8 B per image entry (R + L), plus per-image
//!   first-index (4 B, level ≥ 2) and out-degree count (4 B, level 3);
//! - collective host arrays `H` + image arrays `I`: 8 B per entry, mirrored
//!   per remote rank;
//! - neuron state: 9 f32 arrays (v, i_ex, i_in, r, w_ex, w_in, spike + 2
//!   scratch) per neuron;
//! - spike ring buffers: 2 ports x `delay_slots` x f32 per neuron;
//! - transient sort scratch: 12 B per connection of the largest sort
//!   segment (keys u64 + permutation u32), the dominant Fig. 5 peak term.
//!
//! The *expected number of distinct sources* from a remote rank follows the
//! balls-in-bins form `M·(1 − (1 − 1/(P·M))^(M·K))` which produces exactly
//! the paper's level-0 plateau once `P` exceeds the in-degree: level 0 maps
//! only used sources, so the total image count saturates at `≈ M·K_in`.

use super::MemKind;
use crate::remote::levels::GpuMemLevel;

/// Baseline balanced-network constants (§0.4.2).
pub const NEURONS_PER_SCALE: u64 = 11_250;
pub const K_IN: u64 = 11_250;

/// Bytes per stored connection.
pub const BYTES_PER_CONN: u64 = 16;
/// Bytes per (R, L) map entry.
pub const BYTES_PER_MAP_ENTRY: u64 = 8;
/// f32 state arrays per neuron in the runtime block layout.
pub const STATE_ARRAYS: u64 = 9;
/// Ring-buffer delay slots (2 ports).
pub const DELAY_SLOTS: u64 = 16;
/// Number of segments the preparation sort processes at a time; the
/// transient scratch peak is one segment's keys+permutation.
pub const SORT_SEGMENTS: u64 = 16;

/// NVIDIA A100 (Leonardo Booster custom) device memory.
pub const A100_BYTES: u64 = 64 * (1 << 30);
/// NVIDIA V100 (JUSUF) device memory.
pub const V100_BYTES: u64 = 16 * (1 << 30);

/// Per-rank memory breakdown predicted by the model (bytes).
#[derive(Clone, Copy, Debug, Default)]
pub struct MemBreakdown {
    pub connections: u64,
    pub maps: u64,
    pub first_counts: u64,
    pub collective_hi: u64,
    pub neuron_state: u64,
    pub ring_buffers: u64,
    pub transient_peak: u64,
}

impl MemBreakdown {
    /// Steady-state device bytes.
    pub fn steady(&self) -> u64 {
        self.connections
            + self.maps
            + self.first_counts
            + self.collective_hi
            + self.neuron_state
            + self.ring_buffers
    }

    /// Peak device bytes (steady + transient construction peak).
    pub fn peak(&self) -> u64 {
        self.steady() + self.transient_peak
    }
}

/// Expected number of *distinct* values after `draws` uniform draws from a
/// population of `pop` values.
pub fn expected_distinct(pop: f64, draws: f64) -> f64 {
    if pop <= 0.0 {
        return 0.0;
    }
    pop * (1.0 - (1.0 - 1.0 / pop).powf(draws))
}

/// Predict the per-rank device memory for the scalable balanced network at
/// `scale`, with `procs` MPI processes, at GPU memory level `level`.
pub fn predict_balanced(scale: f64, procs: u64, level: GpuMemLevel) -> MemBreakdown {
    let m = (NEURONS_PER_SCALE as f64 * scale).round(); // neurons per rank
    let k = K_IN as f64; // in-degree per neuron
    let p = procs as f64;
    let conns = m * k; // connections stored per rank (targets local)

    // Incoming connections drawn uniformly over the whole distributed
    // population; per remote source rank the expected distinct sources:
    let draws_per_source_rank = conns / p;
    let distinct_per_rank = expected_distinct(m, draws_per_source_rank);
    let used_images = (p - 1.0).max(0.0) * distinct_per_rank;
    // Level >= 1 creates an image for every source passed to RemoteConnect
    // (the full remote population), regardless of use:
    let all_images = (p - 1.0).max(0.0) * m;
    let images = match level {
        GpuMemLevel::L0 => used_images,
        _ => all_images,
    };

    // --- device-resident structures by level (§0.3.6) ---
    let map_bytes = images * BYTES_PER_MAP_ENTRY as f64;
    let first_bytes = images * 4.0;
    let count_bytes = images * 4.0;
    let (maps_dev, first_counts_dev) = match level {
        GpuMemLevel::L0 | GpuMemLevel::L1 => (0.0, 0.0),
        GpuMemLevel::L2 => (map_bytes, first_bytes),
        GpuMemLevel::L3 => (map_bytes, first_bytes + count_bytes),
    };

    // Collective H/I arrays: H mirrored for every remote rank (4 B), I of
    // the same length (4 B). With level >= 1 every remote neuron appears in
    // H; with level 0 H still holds the union of RemoteConnect source
    // arguments (the full population for this model — H is placement-bound,
    // not flag-bound), but resides on the host for levels 0-1.
    let hi_entries = (p - 1.0).max(0.0) * m;
    let hi_dev = match level {
        GpuMemLevel::L0 | GpuMemLevel::L1 => 0.0,
        _ => hi_entries * 8.0,
    };

    let neuron_state = m * STATE_ARRAYS as f64 * 4.0;
    let ring = (m + images) as f64 * 0.0 + m * DELAY_SLOTS as f64 * 2.0 * 4.0;

    // Transient peak: sort scratch over the largest segment + the
    // RemoteConnect temporaries (l, b, ũ, s̃ over the source set).
    let sort_scratch = conns / SORT_SEGMENTS as f64 * 12.0;
    let rc_temp = m * (4.0 + 1.0 + 4.0 + 4.0);
    let transient = sort_scratch + rc_temp;

    MemBreakdown {
        connections: (conns * BYTES_PER_CONN as f64) as u64,
        maps: maps_dev as u64,
        first_counts: first_counts_dev as u64,
        collective_hi: hi_dev as u64,
        neuron_state: neuron_state as u64,
        ring_buffers: ring as u64,
        transient_peak: transient as u64,
    }
}

/// Which memory the (R, L) maps / first / count structures live in for a
/// given level (used by the runtime structures; duplicated here for the
/// analytic model's documentation value).
pub fn map_residency(level: GpuMemLevel) -> MemKind {
    match level {
        GpuMemLevel::L0 | GpuMemLevel::L1 => MemKind::Host,
        _ => MemKind::Device,
    }
}

/// Model-size rows of Table 1: (nodes, gpus, neurons, synapses) at scale 20.
pub fn table1_row(nodes: u64, gpus_per_node: u64, scale: f64) -> (u64, u64, u64, u64) {
    let gpus = nodes * gpus_per_node;
    let neurons = (NEURONS_PER_SCALE as f64 * scale) as u64 * gpus;
    let synapses = neurons * K_IN;
    (nodes, gpus, neurons, synapses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expected_distinct_limits() {
        // few draws from a large population: ~all distinct
        let d = expected_distinct(1e9, 100.0);
        assert!((d - 100.0).abs() < 0.01);
        // many draws from a small population: saturates at the population
        let d = expected_distinct(100.0, 1e6);
        assert!((d - 100.0).abs() < 1e-6);
    }

    #[test]
    fn table1_matches_paper() {
        // Paper Table 1: 32 nodes, 128 GPUs -> 28.8e6 neurons, 0.32e12 syn
        let (_, gpus, neurons, syn) = table1_row(32, 4, 20.0);
        assert_eq!(gpus, 128);
        assert_eq!(neurons, 28_800_000);
        assert_eq!(syn, 324_000_000_000);
        // 256 nodes -> 230.4e6 neurons, 2.59e12 synapses
        let (_, _, neurons, syn) = table1_row(256, 4, 20.0);
        assert_eq!(neurons, 230_400_000);
        assert!((syn as f64 / 1e12 - 2.592).abs() < 0.01);
    }

    #[test]
    fn level0_plateaus_beyond_indegree() {
        // Paper: from ~3072 nodes (12288 gpus... the paper says 3072 nodes =
        // 12288 ranks? no: 4 GPUs/node -> procs = 4*nodes) the level-0 peak
        // plateaus because P exceeds K_in and the used-image maps saturate.
        let scale = 20.0;
        let a = predict_balanced(scale, 11_250, GpuMemLevel::L0);
        let b = predict_balanced(scale, 22_500, GpuMemLevel::L0);
        let rel = (b.peak() as f64 - a.peak() as f64) / a.peak() as f64;
        assert!(rel.abs() < 0.01, "level-0 peak should plateau, rel={rel}");
    }

    #[test]
    fn higher_levels_grow_with_procs() {
        let scale = 20.0;
        let a = predict_balanced(scale, 128, GpuMemLevel::L3);
        let b = predict_balanced(scale, 1024, GpuMemLevel::L3);
        assert!(b.peak() > a.peak(), "level-3 peak must grow with procs");
    }

    #[test]
    fn levels_ordered_by_device_usage() {
        let scale = 20.0;
        let p = 512;
        let l0 = predict_balanced(scale, p, GpuMemLevel::L0).steady();
        let l1 = predict_balanced(scale, p, GpuMemLevel::L1).steady();
        let l2 = predict_balanced(scale, p, GpuMemLevel::L2).steady();
        let l3 = predict_balanced(scale, p, GpuMemLevel::L3).steady();
        assert!(l0 <= l1 && l1 <= l2 && l2 <= l3, "{l0} {l1} {l2} {l3}");
    }

    #[test]
    fn scale20_fits_a100_at_moderate_procs() {
        // Paper: scale 20 runs on A100 (64 GB) up to 1024 GPUs for all
        // levels except where the map growth exceeds the budget.
        let l0 = predict_balanced(20.0, 1024, GpuMemLevel::L0);
        assert!(l0.peak() < A100_BYTES, "L0 @1024 procs must fit A100");
        // connections dominate (§Discussion: "memory peak depends primarily
        // on the number of connections")
        assert!(l0.connections > l0.steady() / 2);
    }

    #[test]
    fn residency_matches_levels() {
        assert_eq!(map_residency(GpuMemLevel::L0), MemKind::Host);
        assert_eq!(map_residency(GpuMemLevel::L1), MemKind::Host);
        assert_eq!(map_residency(GpuMemLevel::L2), MemKind::Device);
        assert_eq!(map_residency(GpuMemLevel::L3), MemKind::Device);
    }
}
