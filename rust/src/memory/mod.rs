//! GPU/CPU memory accounting.
//!
//! The paper's Fig. 5 characterizes the *peak* GPU memory per process —
//! including transient construction buffers — because the transient peak is
//! what triggers out-of-memory failures and thus defines the scalability
//! limit. Our simulated device tracks every device-side allocation
//! explicitly ([`Tracker`]); [`model`] additionally provides the analytic
//! full-scale predictor used for the paper-scale extrapolations (the dashed
//! "estimated" curves and the A100 limit line).

pub mod model;
pub mod tracker;

pub use tracker::{MemKind, Tracker};
