//! The Multi-Area Model (§0.4.1): 32 vision-related areas of macaque
//! cortex, each a full-thickness 1 mm² microcircuit patch, coupled by
//! cortico-cortical (cc) projections, simulated with point-to-point MPI
//! communication and optional area packing.
//!
//! **Substitution note (DESIGN.md §2):** the original model's inter-area
//! connectivity derives from axonal tracing data that is not shipped here;
//! we generate a *synthetic but structured* connectome with a fixed
//! internal seed — per-area size factors, 2-D area positions with
//! exponential distance-decay of connection density, and hierarchy-like
//! asymmetry — which exercises the same code paths (heterogeneous areas,
//! dense intra-area + sparse inter-area remote connections) with the same
//! macro-structure. Area TH lacks layer 4, as in the original.

use super::microcircuit::{Microcircuit, BG_RATE_HZ};
use super::packing::{pack_areas, AreaWeight, Packing};
use crate::connection::{ConnRule, NodeSet, SynSpec};
use crate::engine::Simulator;
use crate::node::LifParams;
use crate::util::rng::Rng;

/// The 32 vision-related areas of the MAM.
pub const AREA_NAMES: [&str; 32] = [
    "V1", "V2", "VP", "V3", "V3A", "MT", "V4t", "V4", "VOT", "MSTd", "PIP", "PO", "DP",
    "MIP", "MDP", "VIP", "LIP", "PITv", "PITd", "MSTl", "CITv", "CITd", "FEF", "TF",
    "AITv", "FST", "7a", "STPp", "STPa", "46", "AITd", "TH",
];

pub const N_AREAS: usize = 32;
/// Index of area TH (no layer 4).
pub const TH: usize = 31;

/// MAM configuration.
#[derive(Clone, Debug)]
pub struct MamConfig {
    /// per-area neuron downscale (1.0 = natural density, 4.13e6 neurons)
    pub n_scale: f64,
    /// in-degree downscale (weights compensated by 1/k_scale)
    pub k_scale: f64,
    /// cortico-cortical weight multiplier χ: 1.0 = ground state, >1 =
    /// metastable state (the paper simulates the metastable state)
    pub chi: f64,
    /// base cc in-degree per target neuron at k_scale = 1
    pub kcc_base: f64,
}

impl Default for MamConfig {
    fn default() -> Self {
        Self {
            n_scale: 0.002,
            k_scale: 0.002,
            chi: 1.9,
            kcc_base: 1_500.0,
        }
    }
}

/// The synthetic MAM structure (deterministic; independent of the
/// simulation seed so that all ranks and all seeds agree on the network
/// skeleton, like the tracing-data files of the original implementation).
pub struct MamModel {
    pub cfg: MamConfig,
    pub mc: Microcircuit,
    /// per-area size factor (V1 largest)
    pub area_factor: [f64; N_AREAS],
    /// normalized cc connection density `w[target][source]`, zero diagonal
    pub cc_w: [[f64; N_AREAS]; N_AREAS],
    /// inter-area distance (arbitrary units, for delays)
    pub dist: [[f64; N_AREAS]; N_AREAS],
}

impl MamModel {
    pub fn new(cfg: MamConfig) -> Self {
        let mc = Microcircuit::new(cfg.n_scale, cfg.k_scale);
        // fixed structural seed: the "connectivity data files"
        let mut rng = Rng::new(0x4D414D_2032); // "MAM 2"
        let mut area_factor = [1.0f64; N_AREAS];
        let mut pos = [[0.0f64; 2]; N_AREAS];
        for a in 0..N_AREAS {
            area_factor[a] = rng.uniform_range(0.6, 1.4);
            pos[a] = [rng.uniform_range(0.0, 10.0), rng.uniform_range(0.0, 10.0)];
        }
        area_factor[0] = 1.6; // V1 is the largest area
        let mut dist = [[0.0f64; N_AREAS]; N_AREAS];
        let mut cc_w = [[0.0f64; N_AREAS]; N_AREAS];
        for t in 0..N_AREAS {
            for s in 0..N_AREAS {
                let dx = pos[t][0] - pos[s][0];
                let dy = pos[t][1] - pos[s][1];
                dist[t][s] = (dx * dx + dy * dy).sqrt();
            }
        }
        let lambda = 3.0; // decay length of connection density
        for t in 0..N_AREAS {
            let mut row = [0.0f64; N_AREAS];
            let mut sum = 0.0;
            for s in 0..N_AREAS {
                if s == t {
                    continue;
                }
                // distance decay × log-normal-ish heterogeneity (tracing
                // data spans orders of magnitude)
                let lognorm = (rng.normal() * 1.0).exp();
                row[s] = (-dist[t][s] / lambda).exp() * lognorm;
                sum += row[s];
            }
            for s in 0..N_AREAS {
                cc_w[t][s] = if sum > 0.0 { row[s] / sum } else { 0.0 };
            }
        }
        Self {
            cfg,
            mc,
            area_factor,
            cc_w,
            dist,
        }
    }

    /// Scaled population sizes of an area (TH: no layer 4).
    pub fn area_sizes(&self, a: usize) -> [u32; 8] {
        let mut s = self.mc.sizes();
        for x in s.iter_mut() {
            *x = ((*x as f64) * self.area_factor[a]).round().max(2.0) as u32;
        }
        if a == TH {
            s[2] = 0; // L4E
            s[3] = 0; // L4I
        }
        s
    }

    pub fn area_neurons(&self, a: usize) -> u64 {
        self.area_sizes(a).iter().map(|&n| n as u64).sum()
    }

    pub fn total_neurons(&self) -> u64 {
        (0..N_AREAS).map(|a| self.area_neurons(a)).sum()
    }

    /// cc in-degree per target neuron of area `t` from source area `s`.
    pub fn kcc(&self, t: usize, s: usize) -> u32 {
        (self.cfg.kcc_base * self.cc_w[t][s] * self.cfg.k_scale).round() as u32
    }

    /// Packing weight of an area: incoming connections + neurons (§0.4.1).
    pub fn packing_weights(&self) -> Vec<AreaWeight> {
        (0..N_AREAS)
            .map(|a| {
                let sizes = self.area_sizes(a);
                let mut in_conns = 0u64;
                for t in 0..8 {
                    if sizes[t] == 0 {
                        continue;
                    }
                    for s in 0..8 {
                        in_conns += self.mc.indegree(t, s) as u64 * sizes[t] as u64;
                    }
                }
                let kcc_total: u64 = (0..N_AREAS).map(|s| self.kcc(a, s) as u64).sum();
                in_conns += kcc_total * self.area_neurons(a);
                AreaWeight {
                    area: a,
                    weight: in_conns + self.area_neurons(a),
                }
            })
            .collect()
    }

    /// Pack the 32 areas onto `n_gpus` ranks.
    pub fn pack(&self, n_gpus: usize) -> Packing {
        pack_areas(&self.packing_weights(), n_gpus)
    }

    /// Deterministic node layout: for each area, the owning rank and the
    /// node base of each population on that rank. All ranks compute the
    /// same table (the SPMD equivalent of the shared connectivity files).
    pub fn layout(&self, packing: &Packing) -> MamLayout {
        let mut pop_base = vec![[0u32; 8]; N_AREAS];
        let mut poisson_base = vec![[0u32; 8]; N_AREAS];
        for gpu in 0..packing.n_gpus {
            let mut counter = 0u32;
            for a in packing.areas_of(gpu) {
                let sizes = self.area_sizes(a);
                for p in 0..8 {
                    pop_base[a][p] = counter;
                    counter += sizes[p];
                }
                for p in 0..8 {
                    poisson_base[a][p] = counter;
                    counter += 1;
                }
            }
        }
        MamLayout {
            rank_of_area: packing.gpu_of_area.clone(),
            pop_base,
            poisson_base,
        }
    }

    /// Build this rank's share of the MAM (SPMD: every rank runs this with
    /// the same packing).
    pub fn build(&self, sim: &mut Simulator, packing: &Packing) {
        let layout = self.layout(packing);
        let me = sim.rank();
        let params = LifParams::default();
        let dt = sim.cfg.dt_ms;

        // ---- neuron & device creation, in global layout order
        for gpu in 0..packing.n_gpus {
            if gpu != me {
                continue;
            }
            for a in packing.areas_of(gpu) {
                let sizes = self.area_sizes(a);
                for p in 0..8 {
                    sim.create_neurons(sizes[p], &params);
                }
                for p in 0..8 {
                    // background drive: K_ext Poisson synapses folded into
                    // one generator at K_ext × 8 Hz per target
                    let rate = self.mc.k_ext(p) as f64 * BG_RATE_HZ / self.cfg.k_scale
                        * self.cfg.k_scale; // rate at natural K_ext
                    let gen = sim.create_poisson(rate);
                    if sizes[p] > 0 {
                        let targets = NodeSet::range(layout.pop_base[a][p], sizes[p]);
                        sim.connect(
                            &gen,
                            &targets,
                            &ConnRule::AllToAll,
                            &SynSpec::new(self.mc.weight_ext(), 1),
                        );
                    }
                }
            }
        }

        // ---- intra-area connections (local to the owning rank)
        for a in 0..N_AREAS {
            if layout.rank_of_area[a] != me {
                continue;
            }
            let sizes = self.area_sizes(a);
            for t in 0..8 {
                if sizes[t] == 0 {
                    continue;
                }
                for s in 0..8 {
                    let k = self.mc.indegree(t, s);
                    if k == 0 || sizes[s] == 0 {
                        continue;
                    }
                    let s_set = NodeSet::range(layout.pop_base[a][s], sizes[s]);
                    let t_set = NodeSet::range(layout.pop_base[a][t], sizes[t]);
                    let syn = SynSpec {
                        weight: crate::connection::Dist::Normal {
                            mean: self.mc.weight(t, s),
                            sd: 0.1 * self.mc.weight(t, s).abs(),
                        },
                        delay: crate::connection::Dist::Const(
                            self.mc.delay_steps(s, dt) as f64
                        ),
                        port: if s % 2 == 1 { 1 } else { 0 },
                        stdp: None,
                    };
                    sim.connect(&s_set, &t_set, &ConnRule::FixedIndegree { k }, &syn);
                }
            }
        }

        // ---- cortico-cortical projections (remote when areas differ in
        // rank): sources are the supragranular+infragranular excitatory
        // populations (L23E, L5E) of the source area
        for bt in 0..N_AREAS {
            let tau = layout.rank_of_area[bt];
            let t_sizes = self.area_sizes(bt);
            for ba in 0..N_AREAS {
                if ba == bt {
                    continue;
                }
                let k = self.kcc(bt, ba);
                if k == 0 {
                    continue;
                }
                let sigma = layout.rank_of_area[ba];
                let s_sizes = self.area_sizes(ba);
                // source set: L23E ∪ L5E of area ba
                let mut src: Vec<u32> = Vec::new();
                src.extend(
                    layout.pop_base[ba][0]..layout.pop_base[ba][0] + s_sizes[0],
                );
                src.extend(
                    layout.pop_base[ba][4]..layout.pop_base[ba][4] + s_sizes[4],
                );
                if src.is_empty() {
                    continue;
                }
                let s_set = NodeSet::List(src);
                // targets: all populations of bt (one call per population,
                // keeping per-population in-degrees exact)
                for p in 0..8 {
                    if t_sizes[p] == 0 {
                        continue;
                    }
                    let t_set = NodeSet::range(layout.pop_base[bt][p], t_sizes[p]);
                    let w = self.cfg.chi * self.mc.weight_ext();
                    let delay =
                        (15.0 + self.dist[bt][ba] * 1.5).round().min(31.0).max(1.0);
                    let syn = SynSpec {
                        weight: crate::connection::Dist::Const(w),
                        delay: crate::connection::Dist::Const(delay),
                        port: 0,
                        stdp: None,
                    };
                    let rule = ConnRule::FixedIndegree { k };
                    if sigma == tau {
                        if sigma == me {
                            sim.connect(&s_set, &t_set, &rule, &syn);
                        }
                    } else {
                        sim.remote_connect(sigma, &s_set, tau, &t_set, &rule, &syn, None);
                    }
                }
            }
        }
    }
}

/// Deterministic node layout of the packed MAM.
pub struct MamLayout {
    pub rank_of_area: Vec<usize>,
    pub pop_base: Vec<[u32; 8]>,
    pub poisson_base: Vec<[u32; 8]>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SimConfig;
    use crate::harness::run_cluster;

    fn tiny() -> MamModel {
        // k_scale is kept larger than n_scale so that the cc in-degrees
        // (kcc_base · w · k_scale) stay nonzero at laptop scale
        MamModel::new(MamConfig {
            n_scale: 0.0006,
            k_scale: 0.02,
            chi: 1.9,
            kcc_base: 1500.0,
        })
    }

    #[test]
    fn structure_is_deterministic() {
        let a = tiny();
        let b = tiny();
        assert_eq!(a.area_factor, b.area_factor);
        assert_eq!(a.cc_w[3][7], b.cc_w[3][7]);
    }

    #[test]
    fn th_lacks_layer4() {
        let m = tiny();
        let s = m.area_sizes(TH);
        assert_eq!(s[2], 0);
        assert_eq!(s[3], 0);
        assert!(m.area_sizes(0)[2] > 0);
    }

    #[test]
    fn full_scale_neuron_count_matches_paper_order() {
        // natural density: paper quotes 4.13e6 neurons; our synthetic area
        // factors give the same order of magnitude
        let m = MamModel::new(MamConfig {
            n_scale: 1.0,
            k_scale: 1.0,
            chi: 1.0,
            kcc_base: 1500.0,
        });
        let n = m.total_neurons() as f64;
        assert!((2.0e6..6.0e6).contains(&n), "n={n}");
    }

    #[test]
    fn cc_row_normalized() {
        let m = tiny();
        for t in 0..N_AREAS {
            let sum: f64 = m.cc_w[t].iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "area {t} row sum {sum}");
            assert_eq!(m.cc_w[t][t], 0.0);
        }
    }

    #[test]
    fn one_area_per_rank_builds_and_runs() {
        let m = tiny();
        let packing = m.pack(4); // 32 areas on 4 ranks
        let cfg = SimConfig::default();
        let results = run_cluster(
            4,
            &cfg,
            &move |sim: &mut Simulator| {
                let m = tiny();
                let packing = m.pack(4);
                m.build(sim, &packing)
            },
            30.0,
        )
        .unwrap();
        // every rank hosts some areas, neurons and connections
        for r in &results {
            assert!(r.n_neurons > 0, "rank {}", r.rank);
            assert!(r.n_connections > 0);
            assert!(r.n_images > 0, "cc projections must create images");
        }
        // the model should show activity under background drive
        let total_spikes: u64 = results.iter().map(|r| r.n_spikes).sum();
        assert!(total_spikes > 0);
        let _ = packing;
        let _ = m;
    }
}
