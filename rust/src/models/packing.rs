//! Area packing (§0.4.1, Appendix B): distribute the MAM's areas over a
//! smaller number of GPUs while balancing the load, based on the classic
//! 0–1 knapsack problem. The weight of an area is the sum of its total
//! incoming connections and its neuron count; each area is assigned exactly
//! once. The packing runs at model-initialization time from the
//! connectivity data, before any neuron or connection is instantiated.

/// One area's packing weight.
#[derive(Clone, Copy, Debug)]
pub struct AreaWeight {
    pub area: usize,
    /// incoming connections + neurons
    pub weight: u64,
}

/// Assignment of areas to GPUs (one entry per area: the GPU index).
#[derive(Clone, Debug)]
pub struct Packing {
    pub gpu_of_area: Vec<usize>,
    pub n_gpus: usize,
}

impl Packing {
    /// Areas assigned to a GPU.
    pub fn areas_of(&self, gpu: usize) -> Vec<usize> {
        self.gpu_of_area
            .iter()
            .enumerate()
            .filter(|(_, &g)| g == gpu)
            .map(|(a, _)| a)
            .collect()
    }

    /// Load (sum of weights) per GPU.
    pub fn loads(&self, weights: &[AreaWeight]) -> Vec<u64> {
        let mut loads = vec![0u64; self.n_gpus];
        for w in weights {
            loads[self.gpu_of_area[w.area]] += w.weight;
        }
        loads
    }

    /// max/mean load imbalance.
    pub fn imbalance(&self, weights: &[AreaWeight]) -> f64 {
        let loads = self.loads(weights);
        let max = *loads.iter().max().unwrap_or(&0) as f64;
        let mean = loads.iter().sum::<u64>() as f64 / self.n_gpus.max(1) as f64;
        if mean <= 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// Pack areas onto `n_gpus` GPUs.
///
/// Following the paper: the capacity per GPU is the ideal share
/// (total/n_gpus); GPUs are filled one after another by solving a 0–1
/// knapsack over the remaining areas (DP over scaled weights), and the
/// leftovers spill onto the last GPU. A final LPT (longest-processing-time)
/// rebalancing pass fixes pathological spills.
pub fn pack_areas(weights: &[AreaWeight], n_gpus: usize) -> Packing {
    assert!(n_gpus >= 1);
    assert!(!weights.is_empty());
    let n = weights.len();
    let total: u64 = weights.iter().map(|w| w.weight).sum();
    let capacity = total.div_ceil(n_gpus as u64);
    // DP resolution: keep the knapsack table small
    let unit = (capacity / 2048).max(1);

    let mut assigned = vec![usize::MAX; n];
    let mut remaining: Vec<usize> = (0..n).collect();
    for gpu in 0..n_gpus {
        if remaining.is_empty() {
            break;
        }
        if gpu == n_gpus - 1 {
            for &a in &remaining {
                assigned[weights[a].area] = gpu;
            }
            remaining.clear();
            break;
        }
        let cap_units = (capacity / unit) as usize;
        // 0-1 knapsack maximizing packed weight within capacity
        let mut best: Vec<u64> = vec![0; cap_units + 1];
        let mut choice: Vec<Vec<bool>> = vec![vec![false; cap_units + 1]; remaining.len()];
        for (i, &a) in remaining.iter().enumerate() {
            let w_units = ((weights[a].weight + unit - 1) / unit) as usize;
            let value = weights[a].weight;
            if w_units > cap_units {
                continue;
            }
            for c in (w_units..=cap_units).rev() {
                let cand = best[c - w_units] + value;
                if cand > best[c] {
                    best[c] = cand;
                    choice[i][c] = true;
                }
            }
        }
        // backtrack
        let mut c = cap_units;
        let mut taken = vec![false; remaining.len()];
        for i in (0..remaining.len()).rev() {
            if choice[i][c] {
                taken[i] = true;
                let w_units =
                    ((weights[remaining[i]].weight + unit - 1) / unit) as usize;
                c -= w_units;
            }
        }
        // nothing fit (single huge area): force the largest remaining one
        if !taken.iter().any(|&t| t) {
            let (imax, _) = remaining
                .iter()
                .enumerate()
                .max_by_key(|(_, &a)| weights[a].weight)
                .unwrap();
            taken[imax] = true;
        }
        let mut next_remaining = Vec::new();
        for (i, &a) in remaining.iter().enumerate() {
            if taken[i] {
                assigned[weights[a].area] = gpu;
            } else {
                next_remaining.push(a);
            }
        }
        remaining = next_remaining;
    }

    // LPT rebalancing pass: move areas off the most loaded GPU while it
    // reduces the maximum load
    let mut packing = Packing {
        gpu_of_area: assigned,
        n_gpus,
    };
    let mut improved = true;
    while improved {
        improved = false;
        let loads = packing.loads(weights);
        let (hi, &hi_load) = loads.iter().enumerate().max_by_key(|(_, &l)| l).unwrap();
        let (lo, &lo_load) = loads.iter().enumerate().min_by_key(|(_, &l)| l).unwrap();
        if hi == lo {
            break;
        }
        // smallest area on hi that helps
        let mut candidates: Vec<usize> = packing.areas_of(hi);
        candidates.sort_by_key(|&a| weights[a].weight);
        for a in candidates {
            let w = weights[a].weight;
            if lo_load + w < hi_load {
                packing.gpu_of_area[a] = lo;
                improved = true;
                break;
            }
        }
    }
    packing
}

#[cfg(test)]
mod tests {
    use super::*;

    fn weights(ws: &[u64]) -> Vec<AreaWeight> {
        ws.iter()
            .enumerate()
            .map(|(area, &weight)| AreaWeight { area, weight })
            .collect()
    }

    #[test]
    fn every_area_assigned_once() {
        let w = weights(&[5, 9, 3, 7, 1, 8, 2, 6]);
        let p = pack_areas(&w, 3);
        assert_eq!(p.gpu_of_area.len(), 8);
        assert!(p.gpu_of_area.iter().all(|&g| g < 3));
        let total: usize = (0..3).map(|g| p.areas_of(g).len()).sum();
        assert_eq!(total, 8);
    }

    #[test]
    fn single_gpu_takes_everything() {
        let w = weights(&[5, 9, 3]);
        let p = pack_areas(&w, 1);
        assert!(p.gpu_of_area.iter().all(|&g| g == 0));
        assert_eq!(p.loads(&w), vec![17]);
    }

    #[test]
    fn as_many_gpus_as_areas_spreads_them() {
        let w = weights(&[10, 10, 10, 10]);
        let p = pack_areas(&w, 4);
        let loads = p.loads(&w);
        assert!(loads.iter().all(|&l| l == 10), "loads={loads:?}");
    }

    #[test]
    fn balanced_within_factor_two() {
        // 32 synthetic areas, skewed weights (like MAM areas)
        let ws: Vec<u64> = (0..32).map(|i| 100 + (i * 37) % 400).collect();
        let w = weights(&ws);
        for n_gpus in [2, 4, 8, 16] {
            let p = pack_areas(&w, n_gpus);
            let imb = p.imbalance(&w);
            assert!(imb < 1.6, "{n_gpus} gpus: imbalance {imb}");
        }
    }

    #[test]
    fn huge_single_area_does_not_stall() {
        let w = weights(&[1_000_000, 1, 1, 1]);
        let p = pack_areas(&w, 2);
        // the huge area must be alone-ish; all assigned
        assert!(p.gpu_of_area.iter().all(|&g| g < 2));
    }
}
