//! The Potjans–Diesmann cortical microcircuit [46]: 8 populations (L2/3,
//! L4, L5, L6 × E/I) under ~1 mm² of cortex. It is the intra-area building
//! block of the Multi-Area Model (§0.4.1) and the single-area validation
//! workload (Appendix A).
//!
//! Connectivity is given as pairwise connection probabilities; we convert
//! them to in-degrees (`K = p · N_src`) and instantiate `fixed_indegree`
//! connections, the standard downscaling-friendly reading of the model.

/// Population labels in canonical order.
pub const POP_NAMES: [&str; 8] = [
    "L23E", "L23I", "L4E", "L4I", "L5E", "L5I", "L6E", "L6I",
];

/// Full-scale population sizes (neurons).
pub const POP_SIZES: [u32; 8] = [20_683, 5_834, 21_915, 5_479, 4_850, 1_065, 14_395, 2_948];

/// Connection probabilities `P[target][source]` (Potjans & Diesmann 2014,
/// Table 5).
pub const CONN_PROBS: [[f64; 8]; 8] = [
    // from:  L23E    L23I    L4E     L4I     L5E     L5I     L6E     L6I
    [0.1009, 0.1689, 0.0437, 0.0818, 0.0323, 0.0000, 0.0076, 0.0000], // to L23E
    [0.1346, 0.1371, 0.0316, 0.0515, 0.0755, 0.0000, 0.0042, 0.0000], // to L23I
    [0.0077, 0.0059, 0.0497, 0.1350, 0.0067, 0.0003, 0.0453, 0.0000], // to L4E
    [0.0691, 0.0029, 0.0794, 0.1597, 0.0033, 0.0000, 0.1057, 0.0000], // to L4I
    [0.1004, 0.0622, 0.0505, 0.0057, 0.0831, 0.3726, 0.0204, 0.0000], // to L5E
    [0.0548, 0.0269, 0.0257, 0.0022, 0.0600, 0.3158, 0.0086, 0.0000], // to L5I
    [0.0156, 0.0066, 0.0211, 0.0166, 0.0572, 0.0197, 0.0396, 0.2252], // to L6E
    [0.0364, 0.0010, 0.0034, 0.0005, 0.0277, 0.0080, 0.0658, 0.1443], // to L6I
];

/// External (background) in-degrees per population.
pub const K_EXT: [u32; 8] = [1600, 1500, 2100, 1900, 2000, 1900, 2900, 2100];

/// Background Poisson rate per external synapse (spikes/s).
pub const BG_RATE_HZ: f64 = 8.0;

/// Reference synaptic strength (pA): PSC amplitude for PSP ≈ 0.15 mV.
pub const W_REF_PA: f64 = 87.8;
/// Relative inhibitory strength g (inhibitory weight = −g · w).
pub const G_REL: f64 = 4.0;
/// Mean delays (ms): excitatory / inhibitory.
pub const DELAY_E_MS: f64 = 1.5;
pub const DELAY_I_MS: f64 = 0.75;

/// Microcircuit scaled by `n_scale` (population sizes) and `k_scale`
/// (in-degrees; weights are scaled by 1/k_scale to preserve input).
#[derive(Clone, Debug)]
pub struct Microcircuit {
    pub n_scale: f64,
    pub k_scale: f64,
}

impl Microcircuit {
    pub fn new(n_scale: f64, k_scale: f64) -> Self {
        Self { n_scale, k_scale }
    }

    /// Scaled population sizes (≥ 2 neurons each).
    pub fn sizes(&self) -> [u32; 8] {
        let mut out = [0u32; 8];
        for (i, &n) in POP_SIZES.iter().enumerate() {
            out[i] = ((n as f64 * self.n_scale).round() as u32).max(2);
        }
        out
    }

    pub fn total_neurons(&self) -> u64 {
        self.sizes().iter().map(|&n| n as u64).sum()
    }

    /// Scaled in-degree from source population `s` to target `t`
    /// (`K = p · N_src_full · k_scale`).
    pub fn indegree(&self, t: usize, s: usize) -> u32 {
        (CONN_PROBS[t][s] * POP_SIZES[s] as f64 * self.k_scale).round() as u32
    }

    /// Scaled external in-degree.
    pub fn k_ext(&self, t: usize) -> u32 {
        ((K_EXT[t] as f64 * self.k_scale).round() as u32).max(1)
    }

    /// Synaptic weight (pA) for a projection, with the 1/k_scale
    /// compensation and the doubled L4E→L23E exception.
    pub fn weight(&self, t: usize, s: usize) -> f64 {
        let w = W_REF_PA / self.k_scale;
        if s % 2 == 1 {
            -G_REL * w
        } else if t == 0 && s == 2 {
            2.0 * w // L4E -> L23E
        } else {
            w
        }
    }

    /// External drive weight (pA).
    pub fn weight_ext(&self) -> f64 {
        W_REF_PA / self.k_scale
    }

    /// Delay in steps for a projection at `dt_ms`.
    pub fn delay_steps(&self, s: usize, dt_ms: f64) -> u32 {
        let d = if s % 2 == 0 { DELAY_E_MS } else { DELAY_I_MS };
        (d / dt_ms).round().max(1.0) as u32
    }

    /// Total internal synapses at this scaling.
    pub fn total_synapses(&self) -> u64 {
        let sizes = self.sizes();
        let mut total = 0u64;
        for t in 0..8 {
            for s in 0..8 {
                total += self.indegree(t, s) as u64 * sizes[t] as u64;
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_scale_counts_match_paper() {
        let mc = Microcircuit::new(1.0, 1.0);
        assert_eq!(mc.total_neurons(), 77_169);
        // ~0.3e9 synapses at full scale (Potjans-Diesmann: ~0.3 billion)
        let syn = mc.total_synapses();
        assert!((2.5e8..3.5e8).contains(&(syn as f64)), "syn={syn}");
    }

    #[test]
    fn known_indegrees() {
        let mc = Microcircuit::new(1.0, 1.0);
        // K(L23E <- L23E) = 0.1009 * 20683 ≈ 2087
        assert_eq!(mc.indegree(0, 0), 2087);
        // zero-probability projections have zero in-degree
        assert_eq!(mc.indegree(0, 5), 0);
    }

    #[test]
    fn weights_sign_and_exception() {
        let mc = Microcircuit::new(1.0, 1.0);
        assert!(mc.weight(0, 0) > 0.0);
        assert!(mc.weight(0, 1) < 0.0);
        assert_eq!(mc.weight(0, 2), 2.0 * W_REF_PA); // L4E->L23E doubled
        assert_eq!(mc.weight(3, 1), -G_REL * W_REF_PA);
    }

    #[test]
    fn downscaling_preserves_input_strength() {
        let mc = Microcircuit::new(0.1, 0.1);
        // K * w invariant under k_scale
        let full = Microcircuit::new(1.0, 1.0);
        let kw_full = full.indegree(0, 0) as f64 * full.weight(0, 0);
        let kw_down = mc.indegree(0, 0) as f64 * mc.weight(0, 0);
        assert!((kw_full - kw_down).abs() / kw_full < 0.02);
    }

    #[test]
    fn delay_steps_at_reference_dt() {
        let mc = Microcircuit::new(1.0, 1.0);
        assert_eq!(mc.delay_steps(0, 0.1), 15); // 1.5 ms excitatory
        assert_eq!(mc.delay_steps(1, 0.1), 8); // 0.75 ms inhibitory
    }

    #[test]
    fn tiny_scale_keeps_minimum_population() {
        let mc = Microcircuit::new(1e-6, 1e-3);
        assert!(mc.sizes().iter().all(|&n| n >= 2));
    }
}
