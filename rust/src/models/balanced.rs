//! The scalable balanced network (§0.4.2): the GPU rendition of the NEST
//! "HPC benchmark" — a two-population random balanced network [34] with
//! fixed in-degree, distributed over all MPI processes, exchanging spikes
//! with collective MPI communication.
//!
//! Each rank hosts `9000·scale` excitatory and `2250·scale` inhibitory
//! neurons; every neuron receives `K_in,E = 9000·k_scale` excitatory and
//! `K_in,I = 2250·k_scale` inhibitory connections drawn uniformly from the
//! *distributed* populations across all ranks — the distributed random
//! fixed in-degree rule of §0.3.5 (draw (σ̃, s̃, t) triplets, sort by
//! (source rank, source id) as in Eq. 20, then `RemoteConnect` per source
//! rank with the assigned-nodes rule).
//!
//! The paper's in-degree constants are inconsistent (K_in,E=9,000 +
//! K_in,I=2,500 vs K_in=11,250); we follow the original HPC benchmark:
//! 9,000 + 2,250 = 11,250 (documented in DESIGN.md §9).
//!
//! Appendix D's `in_degree_scale` variant is supported: neuron counts
//! divide by it, in-degrees multiply by it, and weights divide by it so the
//! total input (and the per-rank synapse count) stays constant.

use crate::connection::{ConnRule, NodeSet, SynSpec};
use crate::engine::Simulator;
use crate::node::LifParams;
use crate::plasticity::{StdpRule, WeightBound};
use crate::util::rng::Rng;

const BAL_TAG: u64 = 0x62616C61; // "bala"

/// Baseline per-scale neuron counts (HPC benchmark).
pub const NE_PER_SCALE: u32 = 9_000;
pub const NI_PER_SCALE: u32 = 2_250;

/// STDP configuration of the plastic balanced network: trace-based STDP
/// on *all* recurrent excitatory (E-sourced) synapses — E→E and E→I.
/// (NEST's plastic HPC-benchmark variant restricts STDP to E→E; making
/// every E-sourced synapse of a pass plastic keeps the construction —
/// and hence the drawn network — identical to the static twin, which is
/// what the bit-identity tests and the overhead bench rely on.)
/// Amplitudes are expressed NEST-style relative to `w_max`:
/// `a₊ = λ·w_max`, `a₋ = α·λ·w_max` (additive), or `a₊ = λ`,
/// `a₋ = α·λ` (multiplicative soft bounds).
#[derive(Clone, Copy, Debug)]
pub struct StdpScenario {
    /// learning rate λ
    pub lambda: f64,
    /// depression/potentiation asymmetry α
    pub alpha: f64,
    pub tau_plus_ms: f64,
    pub tau_minus_ms: f64,
    /// `w_max = w_max_factor · w_E` (initial weight); `w_min = 0`
    pub w_max_factor: f64,
    /// multiplicative (soft) bounds instead of additive + clamp
    pub multiplicative: bool,
}

impl Default for StdpScenario {
    fn default() -> Self {
        Self {
            lambda: 0.02,
            alpha: 1.0,
            tau_plus_ms: 20.0,
            tau_minus_ms: 20.0,
            w_max_factor: 2.0,
            multiplicative: false,
        }
    }
}

/// Configuration of the scalable balanced network.
#[derive(Clone, Debug)]
pub struct BalancedConfig {
    /// neurons per rank = 11,250 · scale (paper runs scale ∈ {10, 20, 30})
    pub scale: f64,
    /// in-degree fraction of the full 11,250 (1.0 at paper scale; smaller
    /// for laptop-scale runs; weights are compensated by 1/k_scale)
    pub k_scale: f64,
    /// Appendix D in-degree scale: neurons /= ids, K *= ids, w /= ids
    pub in_degree_scale: f64,
    /// excitatory synaptic weight at k_scale=1 (pA)
    pub j_pa: f64,
    /// relative inhibitory strength (w_I = −g · w_E)
    pub g: f64,
    /// external Poisson rate per neuron (spikes/s)
    pub rate_ext_hz: f64,
    /// external synapse weight (pA)
    pub j_ext_pa: f64,
    /// synaptic delay (steps)
    pub delay_steps: u32,
    /// exchange spikes with collective MPI (the paper's choice for this
    /// model); false = point-to-point
    pub collective: bool,
    /// STDP on the recurrent excitatory (E-sourced) synapses, E→E and
    /// E→I alike (`None` = static run); attaching it changes no
    /// construction draw, so the plastic network is the static network
    /// with evolving E-weights
    pub stdp: Option<StdpScenario>,
}

impl Default for BalancedConfig {
    fn default() -> Self {
        Self {
            scale: 0.01,
            k_scale: 0.01,
            in_degree_scale: 1.0,
            // tuned (see EXPERIMENTS.md) so the default downscaled
            // operating point fires at ~8 spikes/s, like the paper's model
            j_pa: 5.0,
            g: 8.0,
            rate_ext_hz: 16_000.0,
            j_ext_pa: 40.0,
            delay_steps: 15,
            collective: true,
            stdp: None,
        }
    }
}

impl BalancedConfig {
    pub fn ne_per_rank(&self) -> u32 {
        ((NE_PER_SCALE as f64 * self.scale / self.in_degree_scale).round() as u32).max(1)
    }
    pub fn ni_per_rank(&self) -> u32 {
        ((NI_PER_SCALE as f64 * self.scale / self.in_degree_scale).round() as u32).max(1)
    }
    pub fn neurons_per_rank(&self) -> u32 {
        self.ne_per_rank() + self.ni_per_rank()
    }
    pub fn kin_e(&self) -> u32 {
        ((NE_PER_SCALE as f64 * self.k_scale * self.in_degree_scale).round() as u32).max(1)
    }
    pub fn kin_i(&self) -> u32 {
        ((NI_PER_SCALE as f64 * self.k_scale * self.in_degree_scale).round() as u32).max(1)
    }
    /// recurrent weights with the k_scale / in_degree_scale compensation
    pub fn w_e(&self) -> f64 {
        self.j_pa / (self.k_scale * self.in_degree_scale)
    }
    pub fn w_i(&self) -> f64 {
        -self.g * self.w_e()
    }
    /// synapses per rank (recurrent only)
    pub fn synapses_per_rank(&self) -> u64 {
        (self.kin_e() as u64 + self.kin_i() as u64) * self.neurons_per_rank() as u64
    }

    /// The [`StdpRule`] of the recurrent excitatory synapses, when the
    /// scenario is plastic.
    pub fn stdp_rule(&self) -> Option<StdpRule> {
        self.stdp.map(|s| {
            let w_max = (self.w_e() * s.w_max_factor) as f32;
            let (a_plus, a_minus, bound) = if s.multiplicative {
                (
                    s.lambda as f32,
                    (s.alpha * s.lambda) as f32,
                    WeightBound::Multiplicative,
                )
            } else {
                (
                    (s.lambda * w_max as f64) as f32,
                    (s.alpha * s.lambda * w_max as f64) as f32,
                    WeightBound::Additive,
                )
            };
            StdpRule {
                tau_plus_ms: s.tau_plus_ms as f32,
                tau_minus_ms: s.tau_minus_ms as f32,
                a_plus,
                a_minus,
                w_min: 0.0,
                w_max,
                bound,
            }
        })
    }
}

/// Build the balanced network on this rank (SPMD: identical on all ranks).
pub fn build_balanced(sim: &mut Simulator, cfg: &BalancedConfig) {
    let ne = cfg.ne_per_rank();
    let ni = cfg.ni_per_rank();
    let params = LifParams::default();
    // node ids: excitatory [0, ne), inhibitory [ne, ne+ni) — identical
    // layout on every rank (required by the distributed in-degree replay)
    let exc = sim.create_neurons(ne, &params);
    let inh = sim.create_neurons(ni, &params);

    // external drive: one Poisson generator, independent realization per
    // target (NEST poisson_generator semantics)
    let gen = sim.create_poisson(cfg.rate_ext_hz);
    let all_local = NodeSet::range(0, ne + ni);
    sim.connect(
        &gen,
        &all_local,
        &ConnRule::AllToAll,
        &SynSpec::new(cfg.j_ext_pa, cfg.delay_steps),
    );
    let _ = (exc, inh);

    let group = cfg
        .collective
        .then(|| sim.register_group((0..sim.n_ranks()).collect()));

    // distributed random fixed in-degree (§0.3.5), one pass per source
    // population (E then I)
    distributed_fixed_indegree(
        sim,
        cfg,
        group,
        /*exc sources*/ true,
    );
    distributed_fixed_indegree(sim, cfg, group, false);
}

/// §0.3.5: every rank replays, for every target rank τ, the same triplet
/// draw stream; the triplets are bucketed by source rank σ (the Eq. 20
/// sort) and handed to `RemoteConnect` with the assigned-nodes rule.
fn distributed_fixed_indegree(
    sim: &mut Simulator,
    cfg: &BalancedConfig,
    group: Option<usize>,
    exc_sources: bool,
) {
    let n_ranks = sim.n_ranks();
    let me = sim.rank();
    let ne = cfg.ne_per_rank();
    let ni = cfg.ni_per_rank();
    let n_local = ne + ni;
    let (k, src_base, src_n) = if exc_sources {
        (cfg.kin_e(), 0u32, ne)
    } else {
        (cfg.kin_i(), ne, ni)
    };
    let mut syn = SynSpec::new(
        if exc_sources { cfg.w_e() } else { cfg.w_i() },
        cfg.delay_steps,
    );
    if exc_sources {
        // plastic scenario: STDP on the recurrent excitatory synapses
        // (both the local and the remote/image-sourced ones)
        syn.stdp = cfg.stdp_rule();
    }
    if n_ranks > 1 {
        // fold the pass's delay bound on every rank, even for the (σ, τ)
        // replays this rank skips below — the exchange-batching interval
        // derived from the bound must agree across the world
        sim.note_remote_delay(&syn);
    }
    let pass_tag = if exc_sources { 0u64 } else { 1u64 };

    for tau in 0..n_ranks {
        // per-(pass, τ) triplet stream, shared by every rank; capture its
        // raw state *before* any draw — the [`ConnRule::TripletBucket`]
        // calls below replay the stream from this state
        let rng = Rng::stream(sim.cfg.seed, &[BAL_TAG, pass_tag, tau as u64]);
        let (state, _) = rng.raw_state();
        // one counting pass over the stream: per-σ bucket sizes, so empty
        // buckets issue no connect call — exactly as when the buckets were
        // materialized eagerly. The draws mirror `triplet_bucket_pairs`.
        let mut counts = vec![0u64; n_ranks];
        {
            let mut rng = Rng::from_raw_state(state, None);
            for _ in 0..n_local {
                for _ in 0..k {
                    let sigma = rng.below(n_ranks as u32) as usize;
                    let _ = rng.below(src_n);
                    counts[sigma] += 1;
                }
            }
        }
        // Eq. 20: process per source rank σ, each bucket sorted by
        // (source, target) inside the rule's replay (sorting positions is
        // equivalent to sorting absolute ids: `src_base` is constant). The
        // RemoteConnect `s` argument is the *full* source subpopulation of
        // rank σ (Eq. 17) — the replayed pairs index into it — so level
        // 0's flagging (only used sources get images) vs level ≥1 (all of
        // s gets images) behaves as in §0.3.6. Skip the (σ, τ) replays
        // that cannot concern this rank: in p2p mode a rank only needs the
        // buckets where it is source or target; in collective mode every
        // member mirrors H, so it replays all of them (the paper's SPMD
        // scripts do). The stream-seeded rule keeps each call's descriptor
        // constant-size, which is what makes procedural connectivity pay
        // off for this model.
        let s_set = NodeSet::range(src_base, src_n);
        let t_set = NodeSet::range(0, n_local);
        for (sigma, &count) in counts.iter().enumerate() {
            let relevant = tau == me || sigma == me || group.is_some();
            if !relevant || count == 0 {
                continue;
            }
            let rule = ConnRule::TripletBucket {
                state,
                k,
                n_ranks: n_ranks as u32,
                sigma: sigma as u32,
            };
            if sigma == tau {
                if sigma == me {
                    sim.connect(&s_set, &t_set, &rule, &syn);
                }
            } else {
                sim.remote_connect(sigma, &s_set, tau, &t_set, &rule, &syn, group);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SimConfig;
    use crate::harness::run_cluster;

    fn small_cfg() -> BalancedConfig {
        BalancedConfig {
            scale: 0.004,      // 45 neurons per rank
            k_scale: 0.004,    // K_in = 45
            ..Default::default()
        }
    }

    #[test]
    fn config_arithmetic() {
        let c = BalancedConfig {
            scale: 20.0,
            k_scale: 1.0,
            ..Default::default()
        };
        assert_eq!(c.ne_per_rank(), 180_000);
        assert_eq!(c.ni_per_rank(), 45_000);
        assert_eq!(c.neurons_per_rank(), 225_000); // paper: 2.25e5 at scale 20
        assert_eq!(c.kin_e() + c.kin_i(), 11_250);
        // paper: 2.53e9 synapses per GPU at scale 20
        assert!((c.synapses_per_rank() as f64 / 2.53e9 - 1.0).abs() < 0.01);
    }

    #[test]
    fn indegree_scale_preserves_synapses_and_input() {
        let base = BalancedConfig {
            scale: 10.0,
            k_scale: 1.0,
            ..Default::default()
        };
        let scaled = BalancedConfig {
            in_degree_scale: 5.0,
            ..base.clone()
        };
        assert_eq!(base.synapses_per_rank(), scaled.synapses_per_rank());
        // K * w invariant
        let kw_base = base.kin_e() as f64 * base.w_e();
        let kw_scaled = scaled.kin_e() as f64 * scaled.w_e();
        assert!((kw_base - kw_scaled).abs() / kw_base < 1e-9);
    }

    #[test]
    fn every_target_gets_exact_indegree() {
        let cfg = small_cfg();
        let sim_cfg = SimConfig::default();
        let results = run_cluster(
            3,
            &sim_cfg,
            &|sim: &mut Simulator| build_balanced(sim, &small_cfg()),
            0.0,
        )
        .unwrap();
        let k_total = (cfg.kin_e() + cfg.kin_i()) as u64;
        let n_local = cfg.neurons_per_rank() as u64;
        // poisson adds n_local conns; recurrent = K_in * n_local
        for r in &results {
            assert_eq!(
                r.n_connections,
                n_local * k_total + n_local,
                "rank {}",
                r.rank
            );
        }
    }

    #[test]
    fn collective_and_p2p_builds_agree_on_network_size() {
        let mut cfg = small_cfg();
        let sim_cfg = SimConfig::default();
        let coll = run_cluster(
            2,
            &sim_cfg,
            &|sim: &mut Simulator| build_balanced(sim, &small_cfg()),
            0.0,
        )
        .unwrap();
        cfg.collective = false;
        let cfg2 = cfg.clone();
        let p2p = run_cluster(
            2,
            &sim_cfg,
            &move |sim: &mut Simulator| build_balanced(sim, &cfg2),
            0.0,
        )
        .unwrap();
        for (a, b) in coll.iter().zip(p2p.iter()) {
            assert_eq!(a.n_connections, b.n_connections);
            assert_eq!(a.n_neurons, b.n_neurons);
        }
    }

    #[test]
    fn balanced_network_fires_moderately() {
        let sim_cfg = SimConfig::default();
        let results = run_cluster(
            2,
            &sim_cfg,
            &|sim: &mut Simulator| build_balanced(sim, &small_cfg()),
            200.0,
        )
        .unwrap();
        for r in &results {
            let rate = r.n_spikes as f64 / r.n_neurons as f64 / 0.2;
            assert!(
                rate > 0.5 && rate < 200.0,
                "rank {} rate {rate} spikes/s out of range",
                r.rank
            );
        }
    }
}
