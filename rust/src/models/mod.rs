//! The benchmark models of §0.4: the cortical microcircuit (the building
//! block of the Multi-Area Model), the 32-area MAM with area packing, and
//! the scalable balanced network (the "HPC benchmark").

pub mod balanced;
pub mod mam;
pub mod microcircuit;
pub mod packing;
