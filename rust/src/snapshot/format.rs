//! The versioned snapshot container (see `rust/DESIGN.md` §10).
//!
//! Layout (all little-endian):
//!
//! ```text
//! offset  size  field
//! 0       8     magic  = "NGSNAPv1"
//! 8       4     format version (u32)
//! 12      4     section count (u32)
//! 16      28*n  section table: tag [u8;4] | offset u64 | len u64 | fnv64 u64
//! ...           section payloads (concatenated, in table order)
//! ```
//!
//! Offsets are absolute file offsets. Every section payload carries an
//! FNV-1a 64 checksum verified on open, so bit rot or a partial write is
//! detected before any state is deserialized. Unknown trailing sections are
//! tolerated (forward compatibility: a newer writer may append sections an
//! older reader ignores); a missing *requested* section is an error.

use anyhow::{bail, Context, Result};

pub const MAGIC: [u8; 8] = *b"NGSNAPv1";
/// Current writer version. History:
///
/// - **2** — CONF grew the exchange-batching fields
///   (`cfg.exchange_interval` + the resolved effective interval);
/// - **3** — plasticity: CONN appends the STDP rule registry and the
///   per-connection rule ids, and a `PLAS` section carries traces and
///   pending plastic arrival events. The v3 CONN fields are strictly
///   appended, so v2 files (all-static by construction) still load.
/// - **4** — procedural connectivity: CONF appends the connectivity-mode
///   byte and a `PROC` section carries the connect-call descriptor store
///   (rules, sets, RNG raw states). Both are strict appends — v2/v3
///   files (materialized by construction) still load.
///
/// Version-1 files predate min-delay exchange batching and are rejected.
pub const FORMAT_VERSION: u32 = 4;
/// Oldest version this build still reads.
pub const MIN_FORMAT_VERSION: u32 = 2;

const TABLE_ENTRY_BYTES: usize = 4 + 8 + 8 + 8;

/// Well-known section tags (one per state-owning subsystem).
pub mod tags {
    /// world + engine configuration (decoded first; contains rank/size)
    pub const CONF: [u8; 4] = *b"CONF";
    /// node index space
    pub const NODE: [u8; 4] = *b"NODE";
    /// population table (state-chunk grouping keys)
    pub const POPS: [u8; 4] = *b"POPS";
    /// connection store (SoA arrays + CSR offsets)
    pub const CONN: [u8; 4] = *b"CONN";
    /// remote routing state ((R,L) maps, S sequences, groups, TP/GQ tables)
    pub const REMT: [u8; 4] = *b"REMT";
    /// neuron state chunks (membrane dynamics SoA)
    pub const CHNK: [u8; 4] = *b"CHNK";
    /// spike ring buffers
    pub const BUFS: [u8; 4] = *b"BUFS";
    /// devices: Poisson generators + spike recorder
    pub const DEVS: [u8; 4] = *b"DEVS";
    /// construction RNG streams (local + aligned are in REMT)
    pub const RNGS: [u8; 4] = *b"RNGS";
    /// plasticity state: traces + pending arrival events (v3, optional —
    /// present iff the network has plastic synapses)
    pub const PLAS: [u8; 4] = *b"PLAS";
    /// procedural connectivity: the connect-call descriptor store (v4,
    /// optional — present iff the run uses procedural connectivity)
    pub const PROC: [u8; 4] = *b"PROC";
}

/// One parsed section-table entry (shared by the in-memory and the
/// file-based reader so the two cannot drift on the entry layout).
#[derive(Clone, Copy)]
struct TableEntry {
    tag: [u8; 4],
    off: u64,
    len: u64,
    sum: u64,
}

impl TableEntry {
    fn parse(e: &[u8]) -> Self {
        debug_assert_eq!(e.len(), TABLE_ENTRY_BYTES);
        Self {
            tag: [e[0], e[1], e[2], e[3]],
            off: u64::from_le_bytes(e[4..12].try_into().unwrap()),
            len: u64::from_le_bytes(e[12..20].try_into().unwrap()),
            sum: u64::from_le_bytes(e[20..28].try_into().unwrap()),
        }
    }

    /// Validate the payload range against the container bounds: it must
    /// lie entirely after the header/table and inside the file.
    fn checked_range(&self, header_len: usize, total_len: u64) -> Result<(u64, u64)> {
        let end = self
            .off
            .checked_add(self.len)
            .context("section range overflows")?;
        if self.off < header_len as u64 || end > total_len {
            bail!(
                "section {} range {}..{end} outside snapshot of {total_len} bytes",
                tag_name(self.tag),
                self.off
            );
        }
        Ok((self.off, end))
    }
}

/// Parse and bounds-check the fixed header; returns the format version
/// and the section count. An out-of-range version fails *here*, before
/// any payload is touched, with an error naming the found and the
/// supported versions — a newer writer's file must never surface as a
/// decode failure mid-stream.
fn parse_header(fixed: &[u8; 16]) -> Result<(u32, usize)> {
    if fixed[..8] != MAGIC {
        bail!(
            "bad snapshot magic {:02x?} (expected {:?})",
            &fixed[..8],
            std::str::from_utf8(&MAGIC).unwrap()
        );
    }
    let version = u32::from_le_bytes(fixed[8..12].try_into().unwrap());
    if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
        bail!(
            "unsupported snapshot format version {version}; this build supports \
             versions {MIN_FORMAT_VERSION}..={FORMAT_VERSION}"
        );
    }
    Ok((
        version,
        u32::from_le_bytes(fixed[12..16].try_into().unwrap()) as usize,
    ))
}

/// FNV-1a 64-bit offset basis (start value for incremental hashing with
/// [`fnv1a64_fold`]).
pub const FNV1A64_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold bytes into a running FNV-1a 64 state — the single implementation
/// behind both the section checksums here and the streaming weight hashes
/// in [`crate::stats::weights`].
pub fn fnv1a64_fold(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a 64-bit.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_fold(FNV1A64_OFFSET, bytes)
}

/// Assembles sections and serializes the container.
#[derive(Default)]
pub struct SnapshotWriter {
    sections: Vec<([u8; 4], Vec<u8>)>,
}

impl SnapshotWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a section (tags must be unique within one snapshot).
    pub fn section(&mut self, tag: [u8; 4], payload: Vec<u8>) {
        debug_assert!(
            self.sections.iter().all(|(t, _)| *t != tag),
            "duplicate snapshot section {:?}",
            tag
        );
        self.sections.push((tag, payload));
    }

    /// Serialize header + table + payloads into one buffer.
    pub fn finish(self) -> Vec<u8> {
        self.finish_with_version(FORMAT_VERSION)
    }

    /// [`SnapshotWriter::finish`] with an explicit format version —
    /// compatibility tooling and the cross-version tests use this to
    /// produce genuine older-version containers.
    pub fn finish_with_version(self, version: u32) -> Vec<u8> {
        let header_len = 16 + self.sections.len() * TABLE_ENTRY_BYTES;
        let total: usize = header_len + self.sections.iter().map(|(_, p)| p.len()).sum::<usize>();
        let mut out = Vec::with_capacity(total);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&version.to_le_bytes());
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        let mut offset = header_len as u64;
        for (tag, payload) in &self.sections {
            out.extend_from_slice(tag);
            out.extend_from_slice(&offset.to_le_bytes());
            out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            out.extend_from_slice(&fnv1a64(payload).to_le_bytes());
            offset += payload.len() as u64;
        }
        for (_, payload) in &self.sections {
            out.extend_from_slice(payload);
        }
        out
    }
}

/// Validated view over a serialized snapshot.
pub struct SnapshotReader<'a> {
    buf: &'a [u8],
    version: u32,
    table: Vec<([u8; 4], usize, usize)>,
}

impl<'a> SnapshotReader<'a> {
    /// Parse and validate the container: magic, version, table bounds and
    /// every section checksum.
    pub fn open(buf: &'a [u8]) -> Result<Self> {
        if buf.len() < 16 {
            bail!("snapshot too short ({} bytes) for the header", buf.len());
        }
        let (version, count) = parse_header(buf[..16].try_into().unwrap())?;
        let header_len = 16 + count * TABLE_ENTRY_BYTES;
        if buf.len() < header_len {
            bail!("snapshot truncated inside the section table");
        }
        let mut table = Vec::with_capacity(count);
        for i in 0..count {
            let entry = TableEntry::parse(
                &buf[16 + i * TABLE_ENTRY_BYTES..16 + (i + 1) * TABLE_ENTRY_BYTES],
            );
            let (off, end) = entry.checked_range(header_len, buf.len() as u64)?;
            let (off, end) = (off as usize, end as usize);
            let actual = fnv1a64(&buf[off..end]);
            if actual != entry.sum {
                bail!(
                    "section {} checksum mismatch: stored {:#018x}, computed {actual:#018x} \
                     — snapshot is corrupt",
                    tag_name(entry.tag),
                    entry.sum
                );
            }
            table.push((entry.tag, off, end - off));
        }
        Ok(Self {
            buf,
            version,
            table,
        })
    }

    /// Format version of the container (within the supported range).
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Payload bytes of a section; error if absent.
    pub fn section(&self, tag: [u8; 4]) -> Result<&'a [u8]> {
        self.table
            .iter()
            .find(|(t, _, _)| *t == tag)
            .map(|&(_, off, len)| &self.buf[off..off + len])
            .with_context(|| format!("snapshot has no {} section", tag_name(tag)))
    }

    /// Payload bytes of a section, or `None` if the snapshot lacks it
    /// (optional sections such as `PLAS`).
    pub fn try_section(&self, tag: [u8; 4]) -> Option<&'a [u8]> {
        self.table
            .iter()
            .find(|(t, _, _)| *t == tag)
            .map(|&(_, off, len)| &self.buf[off..off + len])
    }

    pub fn section_tags(&self) -> impl Iterator<Item = [u8; 4]> + '_ {
        self.table.iter().map(|&(t, _, _)| t)
    }
}

/// Read one section payload (checksum-verified) from a snapshot file
/// without reading or hashing anything else: header + table + the one
/// payload. This keeps header-only inspection (`peek_world`) O(section)
/// instead of O(file) — at production scale the CONN/CHNK sections
/// dominate the file and must not be touched just to learn the world
/// shape.
pub fn read_section_from_file(path: &std::path::Path, tag: [u8; 4]) -> Result<Vec<u8>> {
    use std::io::{Read, Seek, SeekFrom};
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("cannot open snapshot {}", path.display()))?;
    let file_len = f
        .metadata()
        .with_context(|| format!("cannot stat snapshot {}", path.display()))?
        .len();
    let mut fixed = [0u8; 16];
    f.read_exact(&mut fixed)
        .context("snapshot too short for the header")?;
    let (_, count) = parse_header(&fixed)?;
    let header_len = 16 + count * TABLE_ENTRY_BYTES;
    if header_len as u64 > file_len {
        bail!("snapshot truncated inside the section table");
    }
    let mut table = vec![0u8; count * TABLE_ENTRY_BYTES];
    f.read_exact(&mut table)
        .context("snapshot truncated inside the section table")?;
    for e in table.chunks_exact(TABLE_ENTRY_BYTES) {
        let entry = TableEntry::parse(e);
        if entry.tag != tag {
            continue;
        }
        let (off, end) = entry.checked_range(header_len, file_len)?;
        f.seek(SeekFrom::Start(off))
            .context("cannot seek to section payload")?;
        let mut payload = vec![0u8; (end - off) as usize];
        f.read_exact(&mut payload)
            .with_context(|| format!("section {} truncated", tag_name(tag)))?;
        let actual = fnv1a64(&payload);
        if actual != entry.sum {
            bail!(
                "section {} checksum mismatch: stored {:#018x}, computed {actual:#018x} \
                 — snapshot is corrupt",
                tag_name(tag),
                entry.sum
            );
        }
        return Ok(payload);
    }
    bail!("snapshot {} has no {} section", path.display(), tag_name(tag))
}

fn tag_name(tag: [u8; 4]) -> String {
    std::str::from_utf8(&tag)
        .map(|s| s.to_string())
        .unwrap_or_else(|_| format!("{tag:02x?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_reader_roundtrip() {
        let mut w = SnapshotWriter::new();
        w.section(tags::CONF, vec![1, 2, 3]);
        w.section(tags::CONN, vec![9; 100]);
        let bytes = w.finish();
        let r = SnapshotReader::open(&bytes).unwrap();
        assert_eq!(r.section(tags::CONF).unwrap(), &[1, 2, 3]);
        assert_eq!(r.section(tags::CONN).unwrap(), &[9; 100]);
        assert_eq!(r.section_tags().count(), 2);
        assert!(r.section(tags::BUFS).is_err());
    }

    #[test]
    fn empty_snapshot_is_valid() {
        let bytes = SnapshotWriter::new().finish();
        let r = SnapshotReader::open(&bytes).unwrap();
        assert_eq!(r.section_tags().count(), 0);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut w = SnapshotWriter::new();
        w.section(tags::CONF, vec![1]);
        let mut bytes = w.finish();
        bytes[0] ^= 0xFF;
        assert!(SnapshotReader::open(&bytes).is_err());
    }

    #[test]
    fn wrong_version_rejected_naming_found_and_supported() {
        let mut bytes = SnapshotWriter::new().finish();
        bytes[8] = 0xFE;
        let err = SnapshotReader::open(&bytes).unwrap_err().to_string();
        // a newer/unknown version must fail up front with both the found
        // and the supported versions in the message, never as a decode
        // error mid-stream
        assert!(err.contains("version 254"), "{err}");
        assert!(
            err.contains(&format!("{MIN_FORMAT_VERSION}..={FORMAT_VERSION}")),
            "{err}"
        );
    }

    #[test]
    fn older_supported_version_accepted() {
        let mut w = SnapshotWriter::new();
        w.section(tags::CONF, vec![5, 6]);
        let bytes = w.finish_with_version(MIN_FORMAT_VERSION);
        let r = SnapshotReader::open(&bytes).unwrap();
        assert_eq!(r.version(), MIN_FORMAT_VERSION);
        assert_eq!(r.section(tags::CONF).unwrap(), &[5, 6]);
        assert!(r.try_section(tags::PLAS).is_none());
    }

    #[test]
    fn version_one_rejected() {
        let bytes = SnapshotWriter::new().finish_with_version(1);
        let err = SnapshotReader::open(&bytes).unwrap_err().to_string();
        assert!(err.contains("version 1"), "{err}");
    }

    #[test]
    fn flipped_payload_bit_detected() {
        let mut w = SnapshotWriter::new();
        w.section(tags::BUFS, vec![0u8; 64]);
        let mut bytes = w.finish();
        let n = bytes.len();
        bytes[n - 1] ^= 0x01;
        let err = SnapshotReader::open(&bytes).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn truncated_payload_detected() {
        let mut w = SnapshotWriter::new();
        w.section(tags::BUFS, vec![7u8; 64]);
        let bytes = w.finish();
        assert!(SnapshotReader::open(&bytes[..bytes.len() - 8]).is_err());
    }

    #[test]
    fn single_section_file_read_is_selective() {
        let mut w = SnapshotWriter::new();
        w.section(tags::CONF, vec![1, 2, 3]);
        w.section(tags::CONN, vec![9; 50]);
        let bytes = w.finish();
        let path = std::env::temp_dir()
            .join(format!("ngsnap_fmt_test_{}.snap", std::process::id()));
        std::fs::write(&path, &bytes).unwrap();
        assert_eq!(
            read_section_from_file(&path, tags::CONF).unwrap(),
            vec![1, 2, 3]
        );
        assert!(read_section_from_file(&path, tags::BUFS).is_err());
        // corrupt the CONN payload: CONF must still read, CONN must fail
        let mut corrupted = bytes.clone();
        let n = corrupted.len();
        corrupted[n - 1] ^= 1;
        std::fs::write(&path, &corrupted).unwrap();
        assert_eq!(
            read_section_from_file(&path, tags::CONF).unwrap(),
            vec![1, 2, 3]
        );
        assert!(read_section_from_file(&path, tags::CONN).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fnv_known_vector() {
        // FNV-1a 64 of empty input is the offset basis
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        // and of "a" (standard test vector)
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
