//! Snapshot subsystem: checkpoint/restore of constructed networks and
//! mid-run simulator state.
//!
//! The paper makes network *construction* scalable; this subsystem makes it
//! a **one-time** cost. A snapshot is a versioned, per-rank binary file
//! (magic + format version + checksummed section table, [`format`]) holding
//! everything a rank owns after `prepare()`: the connection store, the
//! remote routing tables and (R, L) maps, neuron parameters and dynamic
//! state, ring buffers, device and construction RNG streams.
//!
//! Two modes fall out of one mechanism (saving is legal at any step
//! boundary after `prepare()`):
//!
//! - **construction cache** — save immediately after `prepare()`; later
//!   runs call `Simulator::load_snapshot` and skip Create/Connect/
//!   RemoteConnect/preparation entirely;
//! - **mid-run checkpoint** — save after `n` steps of propagation; the
//!   resumed run continues with bit-identical spike trains, because every
//!   consumed RNG stream and every ring-buffer slot is restored exactly.
//!
//! Since format v3 a snapshot also carries the plasticity state —
//! evolved weights (in CONN, which grew the STDP rule registry and the
//! per-connection rule ids) plus traces and pending arrival events (the
//! optional `PLAS` section) — so a plastic run resumes bit-identically,
//! weights included. Format-v2 files predate plasticity and still load,
//! as fully static networks; versions outside
//! [`MIN_FORMAT_VERSION`]`..=`[`FORMAT_VERSION`] are rejected up front
//! with an error naming both.
//!
//! The per-layer encode/decode impls live next to their types (e.g.
//! `Connections::snapshot_encode` in `connection/store.rs`), built on the
//! small [`codec`] layer; [`crate::engine::Simulator::save_snapshot`] and
//! [`crate::engine::Simulator::load_snapshot`] assemble the container;
//! `harness::run_cluster_from_snapshot` drives a whole thread-rank world
//! from one snapshot file per rank. The on-disk layout is specified in
//! `rust/DESIGN.md` §10.

pub mod codec;
pub mod format;

pub use codec::{Decoder, Encoder};
pub use format::{SnapshotReader, SnapshotWriter, FORMAT_VERSION, MAGIC, MIN_FORMAT_VERSION};

/// Conventional per-rank snapshot file name within a snapshot directory.
pub fn rank_file_name(rank: usize) -> String {
    format!("rank_{rank}.snap")
}
