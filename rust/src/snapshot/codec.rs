//! Minimal binary codec for the snapshot format.
//!
//! Hand-rolled little-endian encoder/decoder (the offline crate set has no
//! serde/bincode). Every multi-byte value is little-endian; every sequence
//! is length-prefixed with a `u64`. The decoder is bounds-checked and
//! returns `anyhow::Error` with byte offsets on truncation, so a corrupt
//! snapshot fails loudly instead of misinterpreting bytes.

use anyhow::{bail, Result};

/// Append-only byte sink.
#[derive(Default)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    #[inline]
    pub fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    #[inline]
    pub fn bool(&mut self, x: bool) {
        self.buf.push(x as u8);
    }

    #[inline]
    pub fn u16(&mut self, x: u16) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    #[inline]
    pub fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    #[inline]
    pub fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    #[inline]
    pub fn f32(&mut self, x: f32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    #[inline]
    pub fn f64(&mut self, x: f64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    /// Sequence length prefix (usize as u64).
    #[inline]
    pub fn seq_len(&mut self, n: usize) {
        self.u64(n as u64);
    }

    pub fn slice_u8(&mut self, xs: &[u8]) {
        self.seq_len(xs.len());
        self.buf.extend_from_slice(xs);
    }

    pub fn slice_u16(&mut self, xs: &[u16]) {
        self.seq_len(xs.len());
        self.buf.reserve(xs.len() * 2);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn slice_u32(&mut self, xs: &[u32]) {
        self.seq_len(xs.len());
        self.buf.reserve(xs.len() * 4);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn slice_u64(&mut self, xs: &[u64]) {
        self.seq_len(xs.len());
        self.buf.reserve(xs.len() * 8);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn slice_f32(&mut self, xs: &[f32]) {
        self.seq_len(xs.len());
        self.buf.reserve(xs.len() * 4);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn string(&mut self, s: &str) {
        self.slice_u8(s.as_bytes());
    }

    /// Memory residency tag (device/host) for level-dependent structures.
    pub fn mem_kind(&mut self, k: crate::memory::MemKind) {
        self.u8(match k {
            crate::memory::MemKind::Device => 0,
            crate::memory::MemKind::Host => 1,
        });
    }

    /// Serialized RNG state: xoshiro256** words + the Box–Muller cache.
    pub fn rng(&mut self, rng: &crate::util::rng::Rng) {
        let (s, cache) = rng.raw_state();
        for w in s {
            self.u64(w);
        }
        match cache {
            None => self.bool(false),
            Some(z) => {
                self.bool(true);
                self.f64(z);
            }
        }
    }
}

/// Bounds-checked cursor over a byte slice.
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_exhausted(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "snapshot truncated: need {n} bytes at offset {}, only {} left",
                self.pos,
                self.remaining()
            );
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    #[inline]
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    #[inline]
    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("invalid bool byte {other} at offset {}", self.pos - 1),
        }
    }

    #[inline]
    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    #[inline]
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    #[inline]
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    #[inline]
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    #[inline]
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Sequence length prefix; rejects lengths that cannot fit in the
    /// remaining bytes (`min_elem_bytes` per element) so corrupt prefixes
    /// cannot trigger huge allocations.
    pub fn seq_len(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.u64()?;
        let n: usize = usize::try_from(n)
            .map_err(|_| anyhow::anyhow!("sequence length {n} overflows usize"))?;
        if min_elem_bytes > 0 && n > self.remaining() / min_elem_bytes {
            bail!(
                "snapshot truncated: sequence of {n} elements (>= {min_elem_bytes} B each) \
                 exceeds the {} remaining bytes",
                self.remaining()
            );
        }
        Ok(n)
    }

    pub fn vec_u8(&mut self) -> Result<Vec<u8>> {
        let n = self.seq_len(1)?;
        Ok(self.take(n)?.to_vec())
    }

    pub fn vec_u16(&mut self) -> Result<Vec<u16>> {
        let n = self.seq_len(2)?;
        let b = self.take(n * 2)?;
        Ok(b.chunks_exact(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]))
            .collect())
    }

    pub fn vec_u32(&mut self) -> Result<Vec<u32>> {
        let n = self.seq_len(4)?;
        let b = self.take(n * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn vec_u64(&mut self) -> Result<Vec<u64>> {
        let n = self.seq_len(8)?;
        let b = self.take(n * 8)?;
        Ok(b.chunks_exact(8)
            .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }

    pub fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let n = self.seq_len(4)?;
        let b = self.take(n * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
            .collect())
    }

    pub fn string(&mut self) -> Result<String> {
        let bytes = self.vec_u8()?;
        String::from_utf8(bytes).map_err(|e| anyhow::anyhow!("invalid utf-8 string: {e}"))
    }

    pub fn mem_kind(&mut self) -> Result<crate::memory::MemKind> {
        match self.u8()? {
            0 => Ok(crate::memory::MemKind::Device),
            1 => Ok(crate::memory::MemKind::Host),
            tag => bail!("unknown memory-kind tag {tag} in snapshot"),
        }
    }

    pub fn rng(&mut self) -> Result<crate::util::rng::Rng> {
        let s = [self.u64()?, self.u64()?, self.u64()?, self.u64()?];
        let cache = if self.bool()? {
            Some(self.f64()?)
        } else {
            None
        };
        Ok(crate::util::rng::Rng::from_raw_state(s, cache))
    }

    /// Assert the cursor consumed the whole buffer (section hygiene).
    pub fn finish(&self) -> Result<()> {
        if !self.is_exhausted() {
            bail!(
                "snapshot section has {} trailing bytes after decode",
                self.remaining()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn scalar_roundtrip() {
        let mut e = Encoder::new();
        e.u8(7);
        e.bool(true);
        e.u16(65_000);
        e.u32(4_000_000_000);
        e.u64(u64::MAX - 1);
        e.f32(-1.5);
        e.f64(std::f64::consts::PI);
        e.string("snap");
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert_eq!(d.u8().unwrap(), 7);
        assert!(d.bool().unwrap());
        assert_eq!(d.u16().unwrap(), 65_000);
        assert_eq!(d.u32().unwrap(), 4_000_000_000);
        assert_eq!(d.u64().unwrap(), u64::MAX - 1);
        assert_eq!(d.f32().unwrap(), -1.5);
        assert_eq!(d.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(d.string().unwrap(), "snap");
        d.finish().unwrap();
    }

    #[test]
    fn slice_roundtrip() {
        let mut e = Encoder::new();
        e.slice_u8(&[1, 2, 3]);
        e.slice_u16(&[9, 10]);
        e.slice_u32(&[7; 5]);
        e.slice_u64(&[u64::MAX]);
        e.slice_f32(&[0.5, -0.25, f32::MIN_POSITIVE]);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert_eq!(d.vec_u8().unwrap(), vec![1, 2, 3]);
        assert_eq!(d.vec_u16().unwrap(), vec![9, 10]);
        assert_eq!(d.vec_u32().unwrap(), vec![7; 5]);
        assert_eq!(d.vec_u64().unwrap(), vec![u64::MAX]);
        assert_eq!(d.vec_f32().unwrap(), vec![0.5, -0.25, f32::MIN_POSITIVE]);
        d.finish().unwrap();
    }

    #[test]
    fn rng_state_roundtrip_continues_stream() {
        let mut rng = Rng::new(99);
        for _ in 0..17 {
            rng.next_u64();
        }
        let _ = rng.normal(); // populate the Box–Muller cache
        let mut e = Encoder::new();
        e.rng(&rng);
        let bytes = e.into_bytes();
        let mut restored = Decoder::new(&bytes).rng().unwrap();
        for _ in 0..100 {
            assert_eq!(restored.normal().to_bits(), rng.normal().to_bits());
            assert_eq!(restored.next_u64(), rng.next_u64());
        }
    }

    #[test]
    fn truncation_is_an_error() {
        let mut e = Encoder::new();
        e.u64(42);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes[..4]);
        assert!(d.u64().is_err());
    }

    #[test]
    fn absurd_length_prefix_rejected() {
        let mut e = Encoder::new();
        e.u64(u64::MAX / 2); // claims ~2^62 elements
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert!(d.vec_u32().is_err());
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut e = Encoder::new();
        e.u32(1);
        e.u32(2);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        d.u32().unwrap();
        assert!(d.finish().is_err());
    }
}
