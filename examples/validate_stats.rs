//! Validation example (Appendix A, quick version): offboard vs onboard
//! construction of the downscaled cortical microcircuit, compared through
//! the EMD protocol over firing rate, CV ISI and Pearson correlation.
//!
//! This is the runnable version of the protocol behind Figs. 7–8 (the
//! bench `fig7_8_validation` runs the fuller sweep).

use nestgpu::connection::{ConnRule, NodeSet, SynSpec};
use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::run_single;
use nestgpu::models::microcircuit::{Microcircuit, BG_RATE_HZ};
use nestgpu::node::LifParams;
use nestgpu::stats::validate::{StatDistributions, ValidationReport};
use nestgpu::stats::SpikeData;
use nestgpu::util::table::median_iqr;

const T_MS: f64 = 300.0;
const SEEDS: u64 = 3;

fn build(sim: &mut Simulator) {
    let mc = Microcircuit::new(0.01, 0.01);
    let sizes = mc.sizes();
    let params = LifParams::default();
    let mut bases = [0u32; 8];
    for p in 0..8 {
        if let NodeSet::Range { start, .. } = sim.create_neurons(sizes[p], &params) {
            bases[p] = start;
        }
    }
    for p in 0..8 {
        let gen = sim.create_poisson(mc.k_ext(p) as f64 * BG_RATE_HZ);
        sim.connect(
            &gen,
            &NodeSet::range(bases[p], sizes[p]),
            &ConnRule::AllToAll,
            &SynSpec::new(mc.weight_ext(), 1),
        );
    }
    for t in 0..8 {
        for s in 0..8 {
            let k = mc.indegree(t, s);
            if k > 0 {
                sim.connect(
                    &NodeSet::range(bases[s], sizes[s]),
                    &NodeSet::range(bases[t], sizes[t]),
                    &ConnRule::FixedIndegree { k },
                    &SynSpec::new(mc.weight(t, s), mc.delay_steps(s, 0.1) as u32),
                );
            }
        }
    }
}

fn run_set(offboard: bool, seed0: u64) -> Vec<StatDistributions> {
    let n = Microcircuit::new(0.01, 0.01).total_neurons() as u32;
    (0..SEEDS)
        .map(|i| {
            let cfg = SimConfig {
                seed: seed0 + i,
                offboard,
                ..Default::default()
            };
            let r = run_single(&cfg, &build, T_MS).expect("run");
            let d = SpikeData::from_events(&r.spikes, 0, n, (T_MS / 0.1) as u32, 0.1);
            StatDistributions::from_spikes(&d, 100, 2.0)
        })
        .collect()
}

fn main() {
    println!("validating onboard vs offboard construction ({SEEDS} seeds/set, T={T_MS} ms)...\n");
    let ref_a = run_set(true, 10);
    let ref_b = run_set(true, 20);
    let new = run_set(false, 30);
    let report = ValidationReport::build(&ref_a, &ref_b, &new);

    for (name, cmp) in [
        ("firing rate ", &report.rates),
        ("CV ISI      ", &report.cv_isi),
        ("correlation ", &report.correlations),
    ] {
        println!(
            "{name}: EMD code-vs-code median {:.4} | seed-vs-seed median {:.4} | compatible: {}",
            median_iqr(&cmp.cross_code).0,
            median_iqr(&cmp.cross_seed).0,
            cmp.compatible(2.0)
        );
    }
    println!(
        "\nverdict: onboard construction statistically compatible with offboard: {}",
        report.all_compatible(2.0)
    );
}
