//! MAM demo: the downscaled 32-area Multi-Area Model packed onto 4 ranks
//! by the knapsack area-packing algorithm, exchanging spikes with
//! point-to-point MPI semantics, in the metastable regime (χ = 1.9).
//! Prints per-area rate statistics and the packing layout.

use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::run_cluster;
use nestgpu::models::mam::{MamConfig, MamModel, AREA_NAMES};
use nestgpu::stats::SpikeData;
use nestgpu::util::table::{fmt_secs, Table};

const RANKS: usize = 4;
const T_MS: f64 = 300.0;

fn mam() -> MamModel {
    MamModel::new(MamConfig {
        n_scale: 0.002,
        k_scale: 0.02,
        chi: 1.9,
        kcc_base: 1500.0,
    })
}

fn main() -> anyhow::Result<()> {
    let m = mam();
    let packing = m.pack(RANKS);
    println!(
        "MAM: {} neurons total, 32 areas on {RANKS} ranks (imbalance {:.2}), \
         chi = {} (metastable), p2p exchange\n",
        m.total_neurons(),
        packing.imbalance(&m.packing_weights()),
        m.cfg.chi
    );
    for gpu in 0..RANKS {
        let areas: Vec<&str> = packing.areas_of(gpu).iter().map(|&a| AREA_NAMES[a]).collect();
        println!("rank {gpu}: {}", areas.join(" "));
    }

    let cfg = SimConfig {
        seed: 7,
        record_spikes: true,
        ..Default::default()
    };
    let results = run_cluster(
        RANKS,
        &cfg,
        &move |sim: &mut Simulator| {
            let m = mam();
            let p = m.pack(sim.n_ranks());
            m.build(sim, &p);
        },
        T_MS,
    )?;

    // per-area rates from each rank's recorder via the layout
    let layout = m.layout(&packing);
    let mut t = Table::new(
        "\nper-area activity",
        &["area", "rank", "neurons", "mean rate (sp/s)"],
    );
    for a in 0..32 {
        let rank = layout.rank_of_area[a];
        let r = &results[rank];
        let n = m.area_neurons(a) as u32;
        let first = layout.pop_base[a][0];
        let data = SpikeData::from_events(&r.spikes, first, n, (T_MS / 0.1) as u32, 0.1);
        t.row(vec![
            AREA_NAMES[a].into(),
            rank.to_string(),
            n.to_string(),
            format!("{:.1}", data.mean_rate()),
        ]);
    }
    t.print();

    let agg_constr: f64 = results
        .iter()
        .map(|r| r.phases.construction().as_secs_f64())
        .sum::<f64>()
        / RANKS as f64;
    let agg_rtf: f64 = results.iter().map(|r| r.rtf).sum::<f64>() / RANKS as f64;
    println!(
        "\nconstruction {} (mean/rank), RTF {:.2}, p2p bytes rank0 {}",
        fmt_secs(agg_constr),
        agg_rtf,
        results[0].p2p_bytes
    );
    Ok(())
}
