//! Quickstart: the 60-second tour of the public API.
//!
//! Builds a 2-rank balanced toy network with collective spike exchange,
//! runs 100 ms of model time on the PJRT backend (the AOT-compiled Pallas
//! LIF kernel) when artifacts are available, and prints rates.
//!
//!     make artifacts && cargo run --release --example quickstart

use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::run_cluster;
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::runtime::BackendKind;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let backend = if artifacts.join("manifest.json").exists() {
        println!("backend: PJRT (AOT artifacts from {})", artifacts.display());
        BackendKind::Pjrt { artifacts }
    } else {
        println!("backend: native (run `make artifacts` for the PJRT path)");
        BackendKind::Native
    };

    let cfg = SimConfig {
        backend,
        seed: 42,
        // remote spike exchange is batched to once per minimum remote
        // synaptic delay by default (bit-identical to per-step exchange,
        // DESIGN.md §11); set Some(1) to force per-step exchange or pass
        // --exchange-interval on the nestgpu CLI
        exchange_interval: None,
        // set `connectivity: Connectivity::Procedural` (CLI:
        // `--connectivity procedural`) to keep static connectivity as
        // compact RNG-seeded descriptors and regenerate fanouts at spike
        // time — bit-identical spike trains at a fraction of the per-rank
        // connectivity memory (DESIGN.md §16)
        // observe the run with `obs: Some(ObsConfig { trace_dir:
        // Some("trace".into()), ..Default::default() })` — per-rank JSONL
        // traces + a merged cross-rank metrics summary on rank 0, analyzed
        // offline with `nestgpu report trace` (DESIGN.md §13; CLI:
        // `--obs-dir` / `--obs-interval`). Results are bit-identical
        // with observability on or off.
        ..Default::default()
    };
    let bal = BalancedConfig {
        scale: 0.01,   // 112 neurons per rank
        k_scale: 0.01, // K_in = 113
        // make the recurrent excitatory synapses plastic with
        // `stdp: Some(StdpScenario::default())` (trace-based STDP,
        // DESIGN.md §12; CLI: `nestgpu balanced --stdp` + --stdp-* knobs);
        // the per-rank weight distribution lands in `SimResult::plastic`
        ..Default::default()
    };
    println!(
        "balanced network: {} neurons/rank, K_in = {}, collective exchange\n",
        bal.neurons_per_rank(),
        bal.kin_e() + bal.kin_i()
    );

    // `run_cluster` runs the ranks as threads of this process; the same
    // model runs bit-identically over real OS processes on the socket
    // transport (DESIGN.md §15) — `nestgpu launch --ranks 2 balanced`, or
    // per process `--comm socket --rank R --world N --rendezvous H:P`;
    // every simulation subcommand prints a world spike hash to compare
    let results = run_cluster(
        2,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &bal),
        100.0,
    )?;

    println!(
        "effective exchange interval: {} step(s)\n",
        results[0].exchange_interval
    );
    for r in &results {
        let rate = r.n_spikes as f64 / r.n_neurons as f64 / 0.1;
        println!(
            "rank {}: {} neurons, {} connections, {} images, {} spikes \
             ({rate:.1} sp/s), RTF {:.2}",
            r.rank, r.n_neurons, r.n_connections, r.n_images, r.n_spikes, r.rtf
        );
    }
    println!("\nconstruction phases (rank 0): {:?}", results[0].phases);

    // repeated runs of the same construction can skip it entirely:
    // `nestgpu serve --listen 127.0.0.1:9123` starts the construction-
    // cache daemon and `nestgpu submit balanced ...` runs jobs against
    // it — identical submits construct once, later ones resume warm
    // from the content-addressed snapshot cache with a bit-identical
    // world spike hash (DESIGN.md §17; `nestgpu submit --stats` shows
    // the hit/miss/eviction counters)
    Ok(())
}
