//! End-to-end driver (EXPERIMENTS.md §E2E): the full system on a real
//! small workload, proving all layers compose.
//!
//! 4 thread-ranks × ~2.8k neurons build the distributed balanced network
//! (the §0.3.5 distributed fixed in-degree rule over all ranks), prepare
//! the collective communication maps, and propagate 1 s of model time with
//! the neuron dynamics executed through **PJRT** — the AOT-lowered JAX
//! model with the Pallas LIF kernel inlined; Python is never on this path.
//! Prints the paper-style phase breakdown, the RTF and the firing-rate
//! statistics.

use nestgpu::engine::{SimConfig, Simulator};
use nestgpu::harness::run_cluster;
use nestgpu::models::balanced::{build_balanced, BalancedConfig};
use nestgpu::runtime::BackendKind;
use nestgpu::stats::SpikeData;
use nestgpu::util::table::{fmt_bytes, fmt_secs, Table};
use std::path::PathBuf;

const RANKS: usize = 4;
const T_MS: f64 = 1000.0;

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first (the e2e driver \
         exercises the PJRT hot path)"
    );
    let cfg = SimConfig {
        backend: BackendKind::Pjrt { artifacts },
        seed: 2025,
        record_spikes: true,
        ..Default::default()
    };
    let bal = BalancedConfig {
        scale: 0.25,    // 2,812 neurons per rank -> 11,250 total
        k_scale: 0.02,  // K_in = 225
        ..Default::default()
    };
    println!(
        "e2e: {RANKS} ranks x {} neurons, K_in={}, {} synapses/rank, \
         collective exchange, PJRT backend, T={T_MS} ms",
        bal.neurons_per_rank(),
        bal.kin_e() + bal.kin_i(),
        bal.synapses_per_rank(),
    );

    let b = bal.clone();
    let results = run_cluster(
        RANKS,
        &cfg,
        &move |sim: &mut Simulator| build_balanced(sim, &b),
        T_MS,
    )?;

    let mut t = Table::new(
        "per-rank results",
        &["rank", "neurons", "conns", "images", "spikes", "rate", "RTF", "dev peak"],
    );
    for r in &results {
        let rate = r.n_spikes as f64 / r.n_neurons as f64 / (T_MS / 1e3);
        t.row(vec![
            r.rank.to_string(),
            r.n_neurons.to_string(),
            r.n_connections.to_string(),
            r.n_images.to_string(),
            r.n_spikes.to_string(),
            format!("{rate:.1}/s"),
            format!("{:.1}", r.rtf),
            fmt_bytes(r.device_peak),
        ]);
    }
    t.print();

    let p = &results[0].phases;
    let mut t2 = Table::new("construction phases (rank 0)", &["phase", "time"]);
    t2.row(vec!["initialization".into(), fmt_secs(p.initialization.as_secs_f64())]);
    t2.row(vec!["node creation".into(), fmt_secs(p.node_creation.as_secs_f64())]);
    t2.row(vec!["local connection".into(), fmt_secs(p.local_connection.as_secs_f64())]);
    t2.row(vec!["remote connection".into(), fmt_secs(p.remote_connection.as_secs_f64())]);
    t2.row(vec!["preparation".into(), fmt_secs(p.preparation.as_secs_f64())]);
    t2.row(vec!["propagation".into(), fmt_secs(p.propagation.as_secs_f64())]);
    t2.print();

    // dynamics sanity: irregular asynchronous activity
    let r0 = &results[0];
    let data = SpikeData::from_events(
        &r0.spikes,
        0,
        r0.n_neurons as u32,
        (T_MS / 0.1) as u32,
        0.1,
    );
    let cv = data.cv_isi();
    let mean_cv = cv.iter().sum::<f64>() / cv.len().max(1) as f64;
    println!(
        "\nrank 0 dynamics: mean rate {:.1} sp/s, mean CV ISI {mean_cv:.2} \
         (balanced networks: irregular, CV near 1)",
        data.mean_rate()
    );
    println!(
        "traffic: collective bytes rank0 = {}",
        fmt_bytes(r0.coll_bytes)
    );
    Ok(())
}
