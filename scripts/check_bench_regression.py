#!/usr/bin/env python3
"""Guard bench results against throughput regressions.

Compares the freshly generated ``BENCH_*.json`` files (written by the
``cargo bench`` smoke runs; stamped by ``rust/src/obs/stamp.rs``) against
the committed baselines in ``scripts/BENCH_baselines.json``.

Only *regressions* fail the check, with a relative tolerance (default
+/-15%, override with ``--tolerance`` or the ``BENCH_TOLERANCE`` env
var):

- higher-is-better metrics (anything named ``*steps_per_s*`` or ending
  in ``_per_s``, e.g. ``records_per_s`` / ``neurons_per_s`` / the
  ``gb_per_s`` merge throughput) fail when they drop more than the
  tolerance below the baseline;
- lower-is-better metrics (``overhead_ratio``, ``overhead_frac``) fail
  when they rise more than the tolerance above it.

Improvements never fail. Metrics without a committed baseline are
reported and skipped, so the check is a no-op until baselines are
captured on a reference machine with ``--write``:

    cargo bench --bench spike_exchange   # etc., SMOKE=1 for CI size
    python3 scripts/check_bench_regression.py --write BENCH_*.json
"""

import argparse
import json
import os
import sys

DEFAULT_BASELINES = os.path.join(os.path.dirname(__file__), "BENCH_baselines.json")
DEFAULT_TOLERANCE = 0.15

# provenance / config fields that are never performance metrics
SKIP_KEYS = {"schema_version", "generated_at", "git_rev", "ranks", "t_ms",
             "scale", "repeats", "min_delay", "interval", "n_plastic"}


def metric_direction(name):
    """'higher' / 'lower' for tracked metrics, None for untracked ones."""
    leaf = name.rsplit(".", 1)[-1]
    if leaf in SKIP_KEYS:
        return None
    if "steps_per_s" in leaf or leaf.endswith("_per_s"):
        return "higher"
    if leaf in ("overhead_ratio", "overhead_frac"):
        return "lower"
    return None


def flatten(value, prefix=""):
    """Numeric leaves of a JSON value as {dotted.path: float}."""
    out = {}
    if isinstance(value, dict):
        for k, v in value.items():
            out.update(flatten(v, f"{prefix}{k}." if prefix else f"{k}."))
    elif isinstance(value, bool):
        pass
    elif isinstance(value, (int, float)):
        out[prefix.rstrip(".")] = float(value)
    return out


def tracked_metrics(path):
    with open(path) as f:
        data = json.load(f)
    name = os.path.splitext(os.path.basename(path))[0]
    out = {}
    for metric, v in sorted(flatten(data).items()):
        direction = metric_direction(metric)
        if direction is not None:
            out[metric] = {"value": v, "dir": direction}
    return name, out


def check(bench_files, baselines, tolerance):
    failures, missing = [], []
    for path in bench_files:
        name, metrics = tracked_metrics(path)
        base_bench = baselines.get("benches", {}).get(name, {})
        for metric, cur in metrics.items():
            base = base_bench.get(metric)
            if base is None:
                missing.append(f"{name}:{metric}")
                continue
            bv, cv = float(base["value"]), cur["value"]
            if cur["dir"] == "higher":
                bad = cv < bv * (1.0 - tolerance)
                delta = (cv - bv) / bv if bv else 0.0
            else:
                bad = cv > bv * (1.0 + tolerance)
                delta = (bv - cv) / bv if bv else 0.0
            status = "FAIL" if bad else "ok"
            print(f"  [{status}] {name}:{metric} = {cv:.4g} "
                  f"(baseline {bv:.4g}, {delta:+.1%} vs worse-by "
                  f">{tolerance:.0%} fails)")
            if bad:
                failures.append(f"{name}:{metric}")
    return failures, missing


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("bench_files", nargs="+", help="BENCH_*.json files to check")
    ap.add_argument("--baselines", default=DEFAULT_BASELINES)
    ap.add_argument("--tolerance",
                    type=float,
                    default=float(os.environ.get("BENCH_TOLERANCE", DEFAULT_TOLERANCE)))
    ap.add_argument("--write", action="store_true",
                    help="capture current results as the new baselines")
    args = ap.parse_args()

    bench_files = [p for p in args.bench_files if os.path.exists(p)]
    for p in set(args.bench_files) - set(bench_files):
        print(f"  [skip] {p}: not found")

    if args.write:
        baselines = {"schema_version": 1, "benches": {}}
        for path in bench_files:
            name, metrics = tracked_metrics(path)
            baselines["benches"][name] = metrics
        with open(args.baselines, "w") as f:
            json.dump(baselines, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baselines written to {args.baselines}")
        return 0

    try:
        with open(args.baselines) as f:
            baselines = json.load(f)
    except FileNotFoundError:
        print(f"no baselines at {args.baselines}; nothing to check")
        return 0

    failures, missing = check(bench_files, baselines, args.tolerance)
    for m in missing:
        print(f"  [skip] {m}: no committed baseline")
    if failures:
        print(f"\n{len(failures)} bench regression(s) beyond "
              f"{args.tolerance:.0%}: {', '.join(failures)}")
        return 1
    print("\nbench regression check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
